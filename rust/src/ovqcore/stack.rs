//! Multi-layer model stacks served through the [`SeqMixer`] trait — the
//! whole-model counterpart of the single-layer state machines. A
//! [`LayerStack`] is N transformer layers, each:
//!
//! ```text
//!   x ─ RMSNorm ─ Wq/Wk/Wv ─ H SeqMixer heads ─ Wo ─(+x)─
//!     ─ RMSNorm ─ gated MLP (silu(Wg h) ⊙ Wu h → Wd) ─(+)─▶ next layer
//! ```
//!
//! and the stack itself implements [`SeqMixer`], so everything built on
//! the trait — [`super::bank::ShardBank`] admission and LRU eviction,
//! the sharded decode engine, continuous batching, traffic replay —
//! serves full model stacks unchanged. A session can be frozen to a
//! snapshot blob mid-prompt at any layer depth and resume
//! bit-identically.
//!
//! Conventions:
//! - **The `keys` stream carries the token embeddings.** A model stack
//!   consumes one `[len, d_model]` activation stream and derives q/k/v
//!   internally via its projections, so `process_chunk`/`process_prefill`
//!   read embeddings from `keys` and ignore `queries`/`values` (they must
//!   only match in shape). The single-token `write(k, _)` stages the
//!   embedding `k` through the stack and buffers the output for the
//!   following `read`.
//! - **Weights are deterministic in the init seed.** Snapshots store the
//!   config + seed and rebuild the weights on restore, so an evicted
//!   session's blob holds only the dynamic per-layer mixer state — the
//!   byte-accounting contract that makes eviction cheap stays intact.
//! - **Prefill ≡ decode, bitwise.** The blocked block path runs every
//!   dense op through [`super::kernels::matmul_rows`] (bit-identical to the
//!   per-token `matvec` by construction) and hands each head's panel to
//!   the mixer's own `process_prefill`; rust/tests/golden.rs compares the
//!   two paths with `to_bits` equality.
//! - **Identity (bare-mixer bridge) mode.** `StackConfig::bare` builds a
//!   1-layer stack with no norms, projections, MLP or residual: the raw
//!   (q, k, v) streams go straight to the heads. This is the golden-test
//!   bridge proving the stack is a strict generalization of the bare
//!   mixers PRs 1–3 served.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::memstate::MixerKind;
use super::mixer::{LayerStat, PrefillMode, Scratch, SeqMixer};
use super::quant::{QuantMode, QuantTensor};
use super::snapshot;

/// RMSNorm epsilon (kept out of the config: one value, everywhere).
const NORM_EPS: f32 = 1e-6;

/// Shape and policy of a [`LayerStack`].
#[derive(Debug, Clone)]
pub struct StackConfig {
    pub layers: usize,
    /// residual-stream width (the stack's d_in == d_out)
    pub d_model: usize,
    /// gated-MLP hidden width
    pub d_ff: usize,
    /// mixer heads per layer
    pub heads: usize,
    /// per-head q/k/v width
    pub d_head: usize,
    /// mixer chunk length (OVQ merge granularity), forwarded to
    /// [`MixerKind::build`]
    pub chunk: usize,
    /// one mixer kind per layer — hybrid schedules mix kinds freely
    pub kinds: Vec<MixerKind>,
    /// bare-mixer bridge mode: no norms/projections/MLP/residual, the raw
    /// (q, k, v) streams feed the heads directly. Requires `layers == 1`
    /// and `heads * d_head == d_model`.
    pub identity: bool,
    /// storage format for the cold tensors — dense layer weights and the
    /// head mixers' dictionaries (CLI `--quant {none,f16,i8}`)
    pub quant: QuantMode,
}

impl StackConfig {
    /// A uniform full stack: every layer serves `kind`.
    pub fn uniform(
        layers: usize,
        d_model: usize,
        d_ff: usize,
        heads: usize,
        d_head: usize,
        chunk: usize,
        kind: MixerKind,
    ) -> StackConfig {
        StackConfig {
            layers,
            d_model,
            d_ff,
            heads,
            d_head,
            chunk,
            kinds: vec![kind; layers],
            identity: false,
            quant: QuantMode::None,
        }
    }

    /// A hybrid full stack with an explicit per-layer schedule — the
    /// depth IS the schedule length.
    pub fn hybrid(
        d_model: usize,
        d_ff: usize,
        heads: usize,
        d_head: usize,
        chunk: usize,
        kinds: Vec<MixerKind>,
    ) -> StackConfig {
        StackConfig {
            layers: kinds.len(),
            d_model,
            d_ff,
            heads,
            d_head,
            chunk,
            kinds,
            identity: false,
            quant: QuantMode::None,
        }
    }

    /// The bare-mixer bridge: one identity layer over `heads` mixers of
    /// `kind` — bit-for-bit the bank-of-mixers workload PRs 1–3 served.
    pub fn bare(kind: MixerKind, heads: usize, d_head: usize, chunk: usize) -> StackConfig {
        StackConfig {
            layers: 1,
            d_model: heads * d_head,
            d_ff: 0,
            heads,
            d_head,
            chunk,
            kinds: vec![kind],
            identity: true,
            quant: QuantMode::None,
        }
    }

    /// Builder: hold the cold tensors (dense weights, head dictionaries)
    /// in `quant` storage.
    pub fn with_quant(mut self, quant: QuantMode) -> StackConfig {
        self.quant = quant;
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.layers == 0 || self.heads == 0 || self.d_head == 0 || self.chunk == 0 {
            bail!(
                "stack config needs layers/heads/d_head/chunk > 0 \
                 (got {}/{}/{}/{})",
                self.layers,
                self.heads,
                self.d_head,
                self.chunk
            );
        }
        if self.kinds.len() != self.layers {
            bail!(
                "stack schedule has {} kinds for {} layers",
                self.kinds.len(),
                self.layers
            );
        }
        if self.identity {
            if self.layers != 1 {
                bail!("identity (bare-mixer) stacks are single-layer, got {}", self.layers);
            }
            if self.heads * self.d_head != self.d_model {
                bail!(
                    "identity stack needs heads * d_head == d_model \
                     ({} * {} != {})",
                    self.heads,
                    self.d_head,
                    self.d_model
                );
            }
        } else if self.d_model == 0 || self.d_ff == 0 {
            bail!("full stack needs d_model/d_ff > 0 (got {}/{})", self.d_model, self.d_ff);
        }
        // the same size bound `from_snapshot` enforces, applied at
        // creation — a stack that validates here is guaranteed to restore
        // from its own eviction blob (nothing constructible is
        // un-thawable). 2^28 weight elements is a 1 GiB f32 model PER
        // SESSION (sessions own their weights), far above servable.
        let row = self
            .heads
            .saturating_mul(self.d_head)
            .saturating_mul(4)
            .saturating_add(self.d_ff.saturating_mul(3))
            .saturating_add(2);
        let weight_elems = self.d_model.saturating_mul(row).saturating_mul(self.layers);
        if self.layers > 4096
            || self.heads > 4096
            || self.chunk > (1 << 20)
            || (weight_elems as u64) > (1u64 << 28)
        {
            bail!(
                "stack too large to serve: {} layers x {} heads, d_model={} d_ff={} \
                 d_head={} chunk={} ({} weight elements exceeds the 2^28 cap)",
                self.layers,
                self.heads,
                self.d_model,
                self.d_ff,
                self.d_head,
                self.chunk,
                weight_elems
            );
        }
        Ok(())
    }
}

/// Deterministic per-(layer, head) mixer seed derived from the stack's
/// init seed — public so golden tests can build the matching bare mixer.
pub fn mixer_seed(init_seed: u64, layer: usize, head: usize) -> u64 {
    mix(init_seed
        ^ (layer as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (head as u64 + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9))
}

/// Deterministic per-(layer, matrix) weight seed.
fn weight_seed(init_seed: u64, layer: usize, tag: u64) -> u64 {
    mix(init_seed ^ (layer as u64 + 1).wrapping_mul(0x94D0_49BB_1331_11EB) ^ (tag << 17))
}

fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// `[rows, cols]` row-major init, normal(0, 1/cols) — the standard
/// fan-in scaling, deterministic in the seed. Shared with the LM head
/// ([`super::lm`]), whose embedding table follows the same
/// weights-are-f(seed) contract.
pub(crate) fn init_matrix(seed: u64, rows: usize, cols: usize) -> Vec<f32> {
    let mut rng = crate::util::rng::Rng::new(seed);
    let scale = 1.0 / (cols as f64).sqrt();
    (0..rows * cols).map(|_| (rng.normal() * scale) as f32).collect()
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// `out[i] = x[i] * w[i] / sqrt(mean(x^2) + eps)` — one row, serial and
/// order-stable, so the blocked and per-token paths share every bit.
fn rmsnorm_row(x: &[f32], w: &[f32], out: &mut [f32]) {
    let d = x.len();
    let mut ss = 0.0f32;
    for &v in x {
        ss += v * v;
    }
    let scale = 1.0 / (ss / d as f32 + NORM_EPS).sqrt();
    for j in 0..d {
        out[j] = x[j] * scale * w[j];
    }
}

/// One transformer layer: dense weights + its mixer heads. Weights are
/// empty in identity mode.
struct StackLayer {
    /// q/k/v projections, `[heads * d_head, d_model]` row-major, in the
    /// config's quant storage (cold: read every token, written never)
    wq: QuantTensor,
    wk: QuantTensor,
    wv: QuantTensor,
    /// output projection, `[d_model, heads * d_head]`
    wo: QuantTensor,
    /// pre-attention / pre-MLP RMSNorm gains, `[d_model]` — tiny and on
    /// the accumulation path, always f32
    norm1: Vec<f32>,
    norm2: Vec<f32>,
    /// gated MLP: gate/up `[d_ff, d_model]`, down `[d_model, d_ff]`
    w_gate: QuantTensor,
    w_up: QuantTensor,
    w_down: QuantTensor,
    heads: Vec<Box<dyn SeqMixer>>,
    /// processing time spent inside this layer, nanoseconds (telemetry,
    /// not state — never serialized)
    busy_ns: f64,
}

impl StackLayer {
    fn new(cfg: &StackConfig, init_seed: u64, layer: usize, build_heads: bool) -> StackLayer {
        let heads = if build_heads {
            (0..cfg.heads)
                .map(|h| {
                    cfg.kinds[layer].build_quant(
                        cfg.d_head,
                        cfg.chunk,
                        mixer_seed(init_seed, layer, h),
                        cfg.quant,
                    )
                })
                .collect()
        } else {
            Vec::with_capacity(cfg.heads)
        };
        let q = cfg.quant;
        if cfg.identity {
            return StackLayer {
                wq: QuantTensor::new(q, 0, 0),
                wk: QuantTensor::new(q, 0, 0),
                wv: QuantTensor::new(q, 0, 0),
                wo: QuantTensor::new(q, 0, 0),
                norm1: Vec::new(),
                norm2: Vec::new(),
                w_gate: QuantTensor::new(q, 0, 0),
                w_up: QuantTensor::new(q, 0, 0),
                w_down: QuantTensor::new(q, 0, 0),
                heads,
                busy_ns: 0.0,
            };
        }
        let (d, hd, dff) = (cfg.d_model, cfg.heads * cfg.d_head, cfg.d_ff);
        let mat = |tag: u64, rows: usize, cols: usize| {
            let w = init_matrix(weight_seed(init_seed, layer, tag), rows, cols);
            QuantTensor::from_f32(q, rows, cols, &w)
        };
        StackLayer {
            wq: mat(1, hd, d),
            wk: mat(2, hd, d),
            wv: mat(3, hd, d),
            wo: mat(4, d, hd),
            norm1: vec![1.0; d],
            norm2: vec![1.0; d],
            w_gate: mat(5, dff, d),
            w_up: mat(6, dff, d),
            w_down: mat(7, d, dff),
            busy_ns: 0.0,
            heads,
        }
    }

    /// Stored weight bytes (quant-aware) + the f32 norm gains.
    fn param_bytes(&self) -> usize {
        self.wq.state_bytes()
            + self.wk.state_bytes()
            + self.wv.state_bytes()
            + self.wo.state_bytes()
            + self.w_gate.state_bytes()
            + self.w_up.state_bytes()
            + self.w_down.state_bytes()
            + (self.norm1.len() + self.norm2.len()) * 4
    }

    fn state_bytes(&self) -> usize {
        self.heads.iter().map(|m| m.state_bytes()).sum()
    }
}

/// Reusable block-sized activation workspace — grown on first use, then
/// zero allocation on the steady-state decode path.
#[derive(Default)]
struct Workspace {
    /// `[len, d_model]` residual stream (the running layer input)
    x: Vec<f32>,
    /// `[len, d_model]` normed activations
    h: Vec<f32>,
    /// `[len, heads * d_head]` projected q/k/v and attention output
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    /// `[len, d_head]` contiguous per-head panels
    pq: Vec<f32>,
    pk: Vec<f32>,
    pv: Vec<f32>,
    po: Vec<f32>,
    /// `[len, d_ff]` MLP gate/up activations
    gate: Vec<f32>,
    up: Vec<f32>,
    /// `[len, d_model]` projection/MLP output staging
    tmp: Vec<f32>,
    /// single-token output buffered between `write` and `read`
    last_out: Vec<f32>,
    /// owned mixer scratch for the write/read path (the trait hands
    /// `read` a scratch, but `write` runs the whole forward)
    own_scratch: Scratch,
}

fn grow(buf: &mut Vec<f32>, n: usize) -> &mut [f32] {
    if buf.len() < n {
        buf.resize(n, 0.0);
    }
    &mut buf[..n]
}

/// A full multi-layer model stack behind the [`SeqMixer`] interface.
pub struct LayerStack {
    cfg: StackConfig,
    init_seed: u64,
    layers: Vec<StackLayer>,
    /// tokens absorbed by the stack (every layer sees every token)
    t: usize,
    ws: Workspace,
}

impl LayerStack {
    /// Build a stack with deterministic seeded weights. Panics on an
    /// invalid config — validate with [`StackConfig::validate`] first
    /// when the shape comes from user input.
    pub fn new(cfg: StackConfig, init_seed: u64) -> LayerStack {
        Self::with_heads(cfg, init_seed, true)
    }

    /// Shared constructor core: weights always, head mixers optionally —
    /// `from_snapshot` restores the heads from blobs instead, so it must
    /// not pay for (and then discard) freshly built ones.
    fn with_heads(cfg: StackConfig, init_seed: u64, build_heads: bool) -> LayerStack {
        cfg.validate().expect("invalid stack config");
        let layers = (0..cfg.layers)
            .map(|l| StackLayer::new(&cfg, init_seed, l, build_heads))
            .collect();
        LayerStack { cfg, init_seed, layers, t: 0, ws: Workspace::default() }
    }

    pub fn cfg(&self) -> &StackConfig {
        &self.cfg
    }

    pub fn init_seed(&self) -> u64 {
        self.init_seed
    }

    /// Weight bytes (shared-model cost, deterministic in the seed — NOT
    /// part of `state_bytes`, which accounts the per-session dynamic
    /// state the eviction contract bills for).
    pub fn param_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.param_bytes()).sum()
    }

    /// Live mixer state bytes per layer.
    pub fn layer_state_bytes(&self) -> Vec<usize> {
        self.layers.iter().map(|l| l.state_bytes()).collect()
    }

    /// Rebuild from a [`snapshot::save`] payload: config + init seed are
    /// read back, weights are regenerated deterministically from the
    /// seed, and every (layer, head) mixer is restored from its nested
    /// self-describing blob.
    pub fn from_snapshot(r: &mut snapshot::Reader<'_>) -> Result<LayerStack> {
        let layers = r.usize()?;
        let d_model = r.usize()?;
        let d_ff = r.usize()?;
        let heads = r.usize()?;
        let d_head = r.usize()?;
        let chunk = r.usize()?;
        let identity = r.bool()?;
        let quant = QuantMode::from_tag(r.u8()?)?;
        let init_seed = r.u64()?;
        let t = r.usize()?;
        // bound the shape BEFORE any allocation or weight init — a
        // corrupt blob claiming a 2^40-wide model must surface as a clean
        // error, never an arithmetic overflow or a wild allocation (the
        // snapshot module's no-panics-on-untrusted-bytes contract). The
        // cap is deliberately far above any servable stack (2^28 weight
        // elements, a 1 GiB f32 model) so everything `save` can produce
        // restores, while keeping the worst allocation a corrupt-but-
        // in-bounds blob can demand survivable (the snapshot fuzz tests
        // flip random bits in real blobs). Saturating math: the bound
        // check itself must not overflow either.
        let row = heads
            .saturating_mul(d_head)
            .saturating_mul(4)
            .saturating_add(d_ff.saturating_mul(3))
            .saturating_add(2);
        let weight_elems = d_model.saturating_mul(row).saturating_mul(layers);
        anyhow::ensure!(
            layers <= 4096
                && heads <= 4096
                && chunk <= (1 << 20)
                && (weight_elems as u64) <= (1u64 << 28),
            "stack snapshot claims an implausible shape ({layers} layers x {heads} heads, \
             d_model={d_model} d_ff={d_ff} d_head={d_head} chunk={chunk})"
        );
        let mut kinds = Vec::with_capacity(layers);
        for _ in 0..layers {
            kinds.push(read_kind(r)?);
        }
        let cfg =
            StackConfig { layers, d_model, d_ff, heads, d_head, chunk, kinds, identity, quant };
        cfg.validate()?;
        // weights are regenerated from the seed (O(params), the price of
        // keeping eviction blobs proportional to dynamic state); the head
        // mixers are NOT built — they are restored from the child blobs
        let mut st = LayerStack::with_heads(cfg, init_seed, false);
        st.t = t;
        for l in 0..layers {
            for h in 0..heads {
                let child = r.bytes()?;
                // check the child's kind against the schedule BEFORE the
                // recursive restore — a corrupt blob nesting containers
                // must fail here, not recurse
                let child_kind = snapshot::peek_kind(child)
                    .with_context(|| format!("stack layer {l} head {h}"))?;
                anyhow::ensure!(
                    child_kind == st.cfg.kinds[l].name(),
                    "stack snapshot layer {l} head {h}: kind {child_kind:?} does not \
                     match schedule {}",
                    st.cfg.kinds[l].name()
                );
                let m = snapshot::restore(child)
                    .with_context(|| format!("stack layer {l} head {h}"))?;
                anyhow::ensure!(
                    m.d_in() == d_head && m.d_out() == d_head,
                    "stack snapshot layer {l} head {h}: dims {}x{} != d_head {d_head}",
                    m.d_in(),
                    m.d_out()
                );
                st.layers[l].heads.push(m);
            }
        }
        Ok(st)
    }

    /// The shared block path: `len` embedding rows through every layer,
    /// layer-blocked (all dense ops via the tiled [`super::kernels::matmul_rows`],
    /// each head's whole panel through one mixer call). Bit-identical to
    /// the serial per-token loop in both modes.
    fn process_block(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
        prefill: bool,
    ) {
        let LayerStack { cfg, layers, ws, t, .. } = self;
        let dh = cfg.d_head;
        let hd = cfg.heads * dh;
        if cfg.identity {
            // bare-mixer bridge: raw (q, k, v) streams, per-head panels
            let len = keys.len() / hd;
            debug_assert_eq!(queries.len(), len * hd);
            debug_assert_eq!(values.len(), len * hd);
            debug_assert_eq!(out.len(), len * hd);
            let t0 = Instant::now();
            let layer = &mut layers[0];
            for (head, mixer) in layer.heads.iter_mut().enumerate() {
                let pq = grow(&mut ws.pq, len * dh);
                gather_head(queries, pq, len, hd, head * dh, dh);
                let pk = grow(&mut ws.pk, len * dh);
                gather_head(keys, pk, len, hd, head * dh, dh);
                let pv = grow(&mut ws.pv, len * dh);
                gather_head(values, pv, len, hd, head * dh, dh);
                let po = grow(&mut ws.po, len * dh);
                let (pq, pk, pv) = (&ws.pq[..len * dh], &ws.pk[..len * dh], &ws.pv[..len * dh]);
                if prefill {
                    mixer.process_prefill(pq, pk, pv, po, scratch);
                } else {
                    mixer.process_chunk(pq, pk, pv, po, scratch);
                }
                scatter_head(&ws.po[..len * dh], out, len, hd, head * dh, dh);
            }
            layer.busy_ns += t0.elapsed().as_nanos() as f64;
            *t += len;
            return;
        }

        let d = cfg.d_model;
        let dff = cfg.d_ff;
        let len = keys.len() / d;
        debug_assert_eq!(queries.len(), len * d);
        debug_assert_eq!(values.len(), len * d);
        debug_assert_eq!(out.len(), len * d);

        // the keys stream carries the embeddings (module docs)
        grow(&mut ws.x, len * d).copy_from_slice(&keys[..len * d]);
        for layer in layers.iter_mut() {
            let t0 = Instant::now();
            // pre-attention norm
            let h = grow(&mut ws.h, len * d);
            for i in 0..len {
                rmsnorm_row(&ws.x[i * d..(i + 1) * d], &layer.norm1, &mut h[i * d..(i + 1) * d]);
            }
            // q/k/v projections, one tiled sweep each
            let hn = &ws.h[..len * d];
            layer.wq.matmul_rows(hn, len, grow(&mut ws.q, len * hd));
            layer.wk.matmul_rows(hn, len, grow(&mut ws.k, len * hd));
            layer.wv.matmul_rows(hn, len, grow(&mut ws.v, len * hd));
            // heads: contiguous panels through each mixer
            grow(&mut ws.attn, len * hd);
            for (head, mixer) in layer.heads.iter_mut().enumerate() {
                gather_head(&ws.q[..len * hd], grow(&mut ws.pq, len * dh), len, hd, head * dh, dh);
                gather_head(&ws.k[..len * hd], grow(&mut ws.pk, len * dh), len, hd, head * dh, dh);
                gather_head(&ws.v[..len * hd], grow(&mut ws.pv, len * dh), len, hd, head * dh, dh);
                let po = grow(&mut ws.po, len * dh);
                let (pq, pk, pv) = (&ws.pq[..len * dh], &ws.pk[..len * dh], &ws.pv[..len * dh]);
                if prefill {
                    mixer.process_prefill(pq, pk, pv, po, scratch);
                } else {
                    mixer.process_chunk(pq, pk, pv, po, scratch);
                }
                let attn = &mut ws.attn[..len * hd];
                scatter_head(&ws.po[..len * dh], attn, len, hd, head * dh, dh);
            }
            // output projection + residual
            layer.wo.matmul_rows(&ws.attn[..len * hd], len, grow(&mut ws.tmp, len * d));
            for (xj, pj) in ws.x[..len * d].iter_mut().zip(&ws.tmp[..len * d]) {
                *xj += pj;
            }
            // pre-MLP norm + gated MLP + residual
            let h = grow(&mut ws.h, len * d);
            for i in 0..len {
                rmsnorm_row(&ws.x[i * d..(i + 1) * d], &layer.norm2, &mut h[i * d..(i + 1) * d]);
            }
            layer.w_gate.matmul_rows(&ws.h[..len * d], len, grow(&mut ws.gate, len * dff));
            layer.w_up.matmul_rows(&ws.h[..len * d], len, grow(&mut ws.up, len * dff));
            for (gj, uj) in ws.gate[..len * dff].iter_mut().zip(&ws.up[..len * dff]) {
                *gj = silu(*gj) * uj;
            }
            layer.w_down.matmul_rows(&ws.gate[..len * dff], len, grow(&mut ws.tmp, len * d));
            for (xj, mj) in ws.x[..len * d].iter_mut().zip(&ws.tmp[..len * d]) {
                *xj += mj;
            }
            layer.busy_ns += t0.elapsed().as_nanos() as f64;
        }
        out[..len * d].copy_from_slice(&ws.x[..len * d]);
        *t += len;
    }
}

/// Copy `[len, width]`-strided head columns into a contiguous
/// `[len, dh]` panel.
fn gather_head(src: &[f32], dst: &mut [f32], len: usize, width: usize, off: usize, dh: usize) {
    for i in 0..len {
        dst[i * dh..(i + 1) * dh].copy_from_slice(&src[i * width + off..i * width + off + dh]);
    }
}

/// Inverse of [`gather_head`].
fn scatter_head(src: &[f32], dst: &mut [f32], len: usize, width: usize, off: usize, dh: usize) {
    for i in 0..len {
        dst[i * width + off..i * width + off + dh].copy_from_slice(&src[i * dh..(i + 1) * dh]);
    }
}

impl SeqMixer for LayerStack {
    fn kind_name(&self) -> &'static str {
        "stack"
    }

    fn d_in(&self) -> usize {
        self.cfg.d_model
    }

    fn d_out(&self) -> usize {
        self.cfg.d_model
    }

    fn tokens(&self) -> usize {
        self.t
    }

    /// Dynamic per-session state only: the per-layer per-head mixer
    /// states. Weights are deterministic in the init seed (rebuilt on
    /// restore), so they are model cost, not session state — see
    /// [`LayerStack::param_bytes`].
    fn state_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.state_bytes()).sum()
    }

    fn update_bytes_per_chunk(&self, l: usize) -> usize {
        self.layers
            .iter()
            .map(|layer| {
                layer.heads.iter().map(|m| m.update_bytes_per_chunk(l)).sum::<usize>()
            })
            .sum()
    }

    /// Stage one token embedding (`k`; `v` is ignored outside identity
    /// mode) through the whole stack and buffer the output for the
    /// following `read` — the write-then-read decode contract.
    fn write(&mut self, k: &[f32], v: &[f32]) {
        if self.cfg.identity {
            let dh = self.cfg.d_head;
            for (head, mixer) in self.layers[0].heads.iter_mut().enumerate() {
                mixer.write(&k[head * dh..(head + 1) * dh], &v[head * dh..(head + 1) * dh]);
            }
            self.t += 1;
            return;
        }
        let d = self.cfg.d_model;
        debug_assert_eq!(k.len(), d);
        let mut out = std::mem::take(&mut self.ws.last_out);
        out.resize(d, 0.0);
        let mut scratch = std::mem::take(&mut self.ws.own_scratch);
        self.process_block(k, k, k, &mut out, &mut scratch, false);
        self.ws.last_out = out;
        self.ws.own_scratch = scratch;
    }

    /// Identity mode answers the query against the heads; a full stack
    /// returns the output buffered by the preceding `write` (the stack
    /// derives its own queries internally).
    fn read(&self, q: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        if self.cfg.identity {
            let dh = self.cfg.d_head;
            for (head, mixer) in self.layers[0].heads.iter().enumerate() {
                let (a, b) = (head * dh, (head + 1) * dh);
                mixer.read(&q[a..b], &mut out[a..b], scratch);
            }
            return;
        }
        let _ = q;
        if self.ws.last_out.len() == out.len() {
            out.copy_from_slice(&self.ws.last_out);
        } else {
            // no preceding write (e.g. a probe on a fresh/restored stack):
            // nothing is buffered, answer zeros
            out.iter_mut().for_each(|o| *o = 0.0);
        }
    }

    fn process_chunk(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        self.process_block(queries, keys, values, out, scratch, false);
    }

    fn process_prefill(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        self.process_block(queries, keys, values, out, scratch, true);
    }

    fn set_prefill_mode(&mut self, mode: PrefillMode) {
        for layer in &mut self.layers {
            for m in &mut layer.heads {
                m.set_prefill_mode(mode);
            }
        }
    }

    /// Every layer's mixer output feeds the next layer, so a stack cannot
    /// skip its read half — the writes-only contract is honored by running
    /// the blocked prefill into a discarded output buffer, which keeps the
    /// state evolution identical to `process_prefill` by construction
    /// (including any chunkwise head mode).
    fn prefill_writes(&mut self, keys: &[f32], values: &[f32], scratch: &mut Scratch) {
        let mut out = vec![0.0f32; values.len()];
        self.process_prefill(keys, keys, values, &mut out, scratch);
    }

    fn flush(&mut self) {
        for layer in &mut self.layers {
            for m in &mut layer.heads {
                m.flush();
            }
        }
    }

    fn snapshot(&self, w: &mut snapshot::Writer) {
        w.usize(self.cfg.layers);
        w.usize(self.cfg.d_model);
        w.usize(self.cfg.d_ff);
        w.usize(self.cfg.heads);
        w.usize(self.cfg.d_head);
        w.usize(self.cfg.chunk);
        w.bool(self.cfg.identity);
        w.u8(self.cfg.quant.tag());
        w.u64(self.init_seed);
        w.usize(self.t);
        for kind in &self.cfg.kinds {
            write_kind(w, *kind);
        }
        for layer in &self.layers {
            for m in &layer.heads {
                w.bytes(&snapshot::save(m.as_ref()));
            }
        }
    }

    fn layer_stats(&self) -> Vec<LayerStat> {
        self.layers
            .iter()
            .enumerate()
            .map(|(l, layer)| LayerStat {
                kind: self.cfg.kinds[l].name().to_string(),
                state_bytes: layer.state_bytes(),
                busy_ns: layer.busy_ns,
                tokens: self.t,
            })
            .collect()
    }
}

/// Tagged [`MixerKind`] serialization for stack snapshots (tag byte +
/// one parameter word; unknown tags fail cleanly on read).
fn write_kind(w: &mut snapshot::Writer, kind: MixerKind) {
    let (tag, param) = match kind {
        MixerKind::FullAttention => (0u8, 0usize),
        MixerKind::SlidingWindow { window } => (1, window),
        MixerKind::Ovq { n_max } => (2, n_max),
        MixerKind::Vq { n } => (3, n),
        MixerKind::LinearAttention => (4, 0),
        MixerKind::Gdn => (5, 0),
    };
    w.u8(tag);
    w.usize(param);
}

fn read_kind(r: &mut snapshot::Reader<'_>) -> Result<MixerKind> {
    let tag = r.u8()?;
    let param = r.usize()?;
    Ok(match tag {
        0 => MixerKind::FullAttention,
        1 => MixerKind::SlidingWindow { window: param },
        2 => MixerKind::Ovq { n_max: param },
        3 => MixerKind::Vq { n: param },
        4 => MixerKind::LinearAttention,
        5 => MixerKind::Gdn,
        other => bail!("unknown mixer-kind tag {other} in stack snapshot"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn small_cfg(layers: usize) -> StackConfig {
        StackConfig::hybrid(
            8,
            16,
            2,
            4,
            8,
            (0..layers)
                .map(|l| {
                    if l % 2 == 0 {
                        MixerKind::Ovq { n_max: 16 }
                    } else {
                        MixerKind::SlidingWindow { window: 12 }
                    }
                })
                .collect(),
        )
    }

    fn run_chunks(st: &mut LayerStack, x: &[f32], arrival: usize) -> Vec<f32> {
        let d = st.d_in();
        let total = x.len() / d;
        let mut out = vec![0.0f32; total * d];
        let mut scratch = Scratch::new();
        let mut i = 0;
        while i < total {
            let len = arrival.min(total - i);
            let sl = &x[i * d..(i + len) * d];
            st.process_chunk(sl, sl, sl, &mut out[i * d..(i + len) * d], &mut scratch);
            i += len;
        }
        out
    }

    #[test]
    fn config_validation_rejects_bad_shapes() {
        assert!(small_cfg(3).validate().is_ok());
        let mut c = small_cfg(3);
        c.kinds.pop();
        assert!(c.validate().is_err(), "schedule/layer mismatch");
        let mut c = small_cfg(2);
        c.d_ff = 0;
        assert!(c.validate().is_err(), "full stack needs d_ff");
        let mut c = StackConfig::bare(MixerKind::Gdn, 2, 4, 8);
        assert!(c.validate().is_ok());
        c.layers = 2;
        c.kinds.push(MixerKind::Gdn);
        assert!(c.validate().is_err(), "identity stacks are single-layer");
        let mut c = StackConfig::bare(MixerKind::Gdn, 2, 4, 8);
        c.d_model = 5;
        assert!(c.validate().is_err(), "identity needs heads*d_head == d_model");
        // the restore-side size cap is enforced at creation too, so every
        // stack that builds is guaranteed to thaw from its eviction blob
        let c = StackConfig::uniform(64, 4096, 16384, 8, 128, 32, MixerKind::Gdn);
        assert!(c.validate().is_err(), "oversized stacks must be rejected up front");
    }

    #[test]
    fn seeded_init_is_deterministic_and_seed_sensitive() {
        let mut rng = Rng::new(1);
        let x = randv(&mut rng, 12 * 8);
        let mut a = LayerStack::new(small_cfg(2), 7);
        let mut b = LayerStack::new(small_cfg(2), 7);
        let mut c = LayerStack::new(small_cfg(2), 8);
        let oa = run_chunks(&mut a, &x, 12);
        let ob = run_chunks(&mut b, &x, 12);
        let oc = run_chunks(&mut c, &x, 12);
        assert_eq!(oa, ob, "same seed must reproduce the same stack");
        assert_ne!(oa, oc, "different seeds must differ");
        assert!(oa.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn arrival_chunking_is_invisible_bitwise() {
        // per-token decode vs blocked decode vs prefill: one stream of
        // bits, regardless of delivery granularity or path
        let mut rng = Rng::new(2);
        let total = 37usize;
        let x = randv(&mut rng, total * 8);
        let mut one = LayerStack::new(small_cfg(3), 5);
        let mut many = LayerStack::new(small_cfg(3), 5);
        let mut pre = LayerStack::new(small_cfg(3), 5);
        let o1 = run_chunks(&mut one, &x, 1);
        let o2 = run_chunks(&mut many, &x, 11);
        let mut o3 = vec![0.0f32; total * 8];
        let mut scratch = Scratch::new();
        pre.process_prefill(&x, &x, &x, &mut o3, &mut scratch);
        for i in 0..o1.len() {
            assert_eq!(o1[i].to_bits(), o2[i].to_bits(), "chunked decode diverged at {i}");
            assert_eq!(o1[i].to_bits(), o3[i].to_bits(), "prefill diverged at {i}");
        }
        assert_eq!(one.tokens(), total);
        assert_eq!(pre.tokens(), total);
    }

    #[test]
    fn write_read_loop_matches_process_chunk() {
        let mut rng = Rng::new(3);
        let total = 9usize;
        let x = randv(&mut rng, total * 8);
        let mut chunked = LayerStack::new(small_cfg(2), 11);
        let want = run_chunks(&mut chunked, &x, total);
        let mut serial = LayerStack::new(small_cfg(2), 11);
        let mut scratch = Scratch::new();
        let mut got = vec![0.0f32; total * 8];
        for i in 0..total {
            let row = &x[i * 8..(i + 1) * 8];
            serial.write(row, row);
            serial.read(row, &mut got[i * 8..(i + 1) * 8], &mut scratch);
        }
        assert_eq!(
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn state_and_param_accounting() {
        let cfg = small_cfg(4);
        let mut st = LayerStack::new(cfg.clone(), 1);
        assert_eq!(st.state_bytes(), 0, "fresh stack has no dynamic state");
        let d = cfg.d_model;
        let hd = cfg.heads * cfg.d_head;
        let per_layer =
            (3 * hd * d + d * hd + 2 * d + 2 * cfg.d_ff * d + d * cfg.d_ff) * 4;
        assert_eq!(st.param_bytes(), cfg.layers * per_layer);

        let mut rng = Rng::new(4);
        let x = randv(&mut rng, 24 * d);
        run_chunks(&mut st, &x, 8);
        st.flush();
        assert_eq!(st.tokens(), 24);
        let per_layer_state = st.layer_state_bytes();
        assert_eq!(per_layer_state.len(), 4);
        assert_eq!(per_layer_state.iter().sum::<usize>(), st.state_bytes());
        // per-layer split carries the schedule's kinds and busy time
        let stats = st.layer_stats();
        assert_eq!(stats.len(), 4);
        assert_eq!(stats[0].kind, "ovq");
        assert_eq!(stats[1].kind, "sliding_window");
        assert!(stats.iter().all(|s| s.tokens == 24));
        assert!(stats.iter().all(|s| s.busy_ns > 0.0));
    }

    #[test]
    fn quantized_stack_runs_and_refreezes_bit_exactly() {
        // cold-tensor storage end to end at the stack level: lossy modes
        // produce finite outputs close to f32, param/state bytes shrink,
        // and snapshot -> restore -> snapshot is byte-identical (weights
        // regenerate from the seed and requantize deterministically; the
        // dictionaries thaw in stored form)
        let mut rng = Rng::new(21);
        let x = randv(&mut rng, 24 * 8);
        let mut base = LayerStack::new(small_cfg(2), 7);
        let want = run_chunks(&mut base, &x, 8);
        for quant in [QuantMode::F16, QuantMode::I8] {
            let cfg = small_cfg(2).with_quant(quant);
            let mut st = LayerStack::new(cfg, 7);
            let got = run_chunks(&mut st, &x, 8);
            assert!(got.iter().all(|v| v.is_finite()), "{quant:?}");
            // same model, lossy weights: outputs track the f32 stack
            // (loose bound — mixer assignments may flip under quantization,
            // this guards against blow-ups, not bit drift)
            let err: f32 = want
                .iter()
                .zip(&got)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max);
            assert!(err < 5.0, "{quant:?}: max deviation {err} vs f32 stack");
            assert!(st.param_bytes() < base.param_bytes(), "{quant:?}: params must shrink");
            st.flush();
            let blob = snapshot::save(&st);
            let m = snapshot::restore(&blob).unwrap();
            assert_eq!(snapshot::save(m.as_ref()), blob, "{quant:?}: refreeze differs");
            assert_eq!(m.state_bytes(), st.state_bytes());
        }
        // i8 weights shrink toward 4x; at these tiny test dims (d=8) the
        // per-row f32 scales cost relatively more, so expect >= 2.5x
        let i8_stack = LayerStack::new(small_cfg(2).with_quant(QuantMode::I8), 7);
        let ratio = base.param_bytes() as f64 / i8_stack.param_bytes() as f64;
        assert!(ratio >= 2.5, "i8 param shrink only {ratio:.2}x");
    }

    #[test]
    fn identity_stack_passes_raw_streams_to_the_heads() {
        // 2 heads of GDN behind the bridge == 2 bare GDNs on the packed
        // head slices, bit for bit
        let (heads, dh, total) = (2usize, 4usize, 10usize);
        let hd = heads * dh;
        let mut rng = Rng::new(5);
        let q = randv(&mut rng, total * hd);
        let k = randv(&mut rng, total * hd);
        let v = randv(&mut rng, total * hd);
        let mut st = LayerStack::new(StackConfig::bare(MixerKind::Gdn, heads, dh, 8), 3);
        let mut out = vec![0.0f32; total * hd];
        let mut scratch = Scratch::new();
        st.process_chunk(&q, &k, &v, &mut out, &mut scratch);
        for head in 0..heads {
            let mut bare = MixerKind::Gdn.build(dh, 8, mixer_seed(3, 0, head));
            for i in 0..total {
                let row = i * hd + head * dh;
                bare.write(&k[row..row + dh], &v[row..row + dh]);
                let mut o = vec![0.0f32; dh];
                bare.read(&q[row..row + dh], &mut o, &mut scratch);
                for j in 0..dh {
                    assert_eq!(
                        out[row + j].to_bits(),
                        o[j].to_bits(),
                        "head {head} token {i} dim {j}"
                    );
                }
            }
        }
        assert_eq!(st.tokens(), total);
    }

    #[test]
    fn kind_tags_round_trip() {
        let kinds = [
            MixerKind::FullAttention,
            MixerKind::SlidingWindow { window: 256 },
            MixerKind::Ovq { n_max: 8192 },
            MixerKind::Vq { n: 64 },
            MixerKind::LinearAttention,
            MixerKind::Gdn,
        ];
        let mut w = snapshot::Writer::new();
        for k in kinds {
            write_kind(&mut w, k);
        }
        let buf = w.into_bytes();
        let mut r = snapshot::Reader::new(&buf);
        for k in kinds {
            assert_eq!(read_kind(&mut r).unwrap(), k);
        }
        let mut w = snapshot::Writer::new();
        w.u8(99);
        w.usize(0);
        let buf = w.into_bytes();
        assert!(read_kind(&mut snapshot::Reader::new(&buf)).is_err());
    }
}
