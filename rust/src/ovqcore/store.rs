//! Tiered session-blob storage: a bounded RAM cache in front of an
//! asynchronous disk spill tier, plus the copy-on-write shared-prefix
//! cache.
//!
//! `ShardBank` owns one [`TieredStore`] per shard. Evicted session
//! blobs land in the RAM tier; when the RAM tier exceeds its byte
//! budget the coldest blobs are queued to a per-shard writeback thread
//! that frames them (magic | length | checksum) and writes them to the
//! spill directory. A spilled session's RAM cost collapses to an index
//! entry. Restores read the frame back, verify length and checksum,
//! and route any corruption through the typed [`SnapshotError`] path —
//! a torn file is a clean error, never a panic.
//!
//! The [`PrefixCache`] is engine-wide (shared across shards): the
//! first session to prefill a given prompt prefix freezes its packed
//! snapshot as an immutable `Arc<[u8]>` template keyed by the prefix
//! hash; later sessions fork from the template bit-identically instead
//! of re-running the prefill.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;

use super::snapshot::SnapshotError;
use crate::util::obs::Registry;

/// Magic word framing every spilled blob on disk: `b"OVQD"` little-endian
/// (`D` for the disk tier; snapshots themselves carry `b"OVQS"`).
pub const SPILL_MAGIC: u32 = 0x4451_564F;

/// Frame header size on disk: magic u32 | payload length u64 | checksum u64.
const FRAME_HEADER: usize = 4 + 8 + 8;

/// RAM cost we account for a disk-spilled session: one index entry
/// (session id + length) — the whole point of the disk tier.
pub const INDEX_ENTRY_BYTES: usize = std::mem::size_of::<(u64, usize)>();

/// FNV-1a 64-bit checksum over a byte slice. Dependency-free and
/// deterministic; strong enough to catch torn writes and bit flips,
/// which is all the disk tier needs (it is not a cryptographic seal).
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Live tier gauges shared between shard-local stores and the engine
/// handle, so `/v1/stats` can report spill activity while the engine
/// is still running (ShardReports only exist after worker exit).
#[derive(Debug, Default)]
pub struct TierStats {
    pub spills: AtomicUsize,
    pub disk_restores: AtomicUsize,
    pub disk_bytes: AtomicUsize,
    pub disk_sessions: AtomicUsize,
}

impl TierStats {
    /// Join a metrics registry as render-time views over these atomics
    /// — the `/metrics` exposition reads the same storage `/v1/stats`
    /// and the shard reports already use, no double counting.
    pub fn register_metrics(self: &Arc<Self>, reg: &Registry) {
        let views: [(&str, fn(&TierStats) -> usize); 4] = [
            ("ovq_tier_spills_total", |t| t.spills.load(Ordering::Relaxed)),
            ("ovq_tier_disk_restores_total", |t| t.disk_restores.load(Ordering::Relaxed)),
            ("ovq_tier_disk_bytes", |t| t.disk_bytes.load(Ordering::Relaxed)),
            ("ovq_tier_disk_sessions", |t| t.disk_sessions.load(Ordering::Relaxed)),
        ];
        for (name, read) in views {
            let me = Arc::clone(self);
            reg.gauge_fn(name, &[], move || read(&me) as f64);
        }
    }
}

/// Configuration for a shard's tiered store.
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// Directory for spilled blobs; `None` disables the disk tier
    /// entirely (pure-RAM store, the pre-tier behaviour).
    pub spill_dir: Option<PathBuf>,
    /// Byte budget for the RAM blob tier; blobs beyond it are queued
    /// for writeback (coldest first).
    pub ram_budget: usize,
    /// Optional engine-shared live gauges mirrored on spill/restore.
    pub shared: Option<Arc<TierStats>>,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig { spill_dir: None, ram_budget: usize::MAX / 2, shared: None }
    }
}

struct RamEntry {
    bytes: Arc<Vec<u8>>,
    touch: u64,
    /// Generation of the writeback in flight for this blob, if any.
    pending: Option<u64>,
}

struct WriteJob {
    id: u64,
    gen: u64,
    bytes: Arc<Vec<u8>>,
    path: PathBuf,
}

struct WriteDone {
    id: u64,
    gen: u64,
    len: usize,
    ok: bool,
}

/// Two-tier (RAM + disk) blob store with LRU writeback.
///
/// Single-owner like the `ShardBank` that embeds it; the only
/// concurrency is the private writeback thread, coordinated over
/// channels with generation tags so a `take()` racing a writeback can
/// never resurrect stale bytes.
pub struct TieredStore {
    dir: Option<PathBuf>,
    budget: usize,
    ram: HashMap<u64, RamEntry>,
    ram_bytes_: usize,
    /// Disk index: session id -> payload length of the blob on disk.
    disk: HashMap<u64, usize>,
    disk_bytes_: usize,
    clock: u64,
    gen: u64,
    outstanding: usize,
    tx: Option<Sender<WriteJob>>,
    done_rx: Option<Receiver<WriteDone>>,
    writer: Option<thread::JoinHandle<()>>,
    shared: Option<Arc<TierStats>>,
    /// Blobs handed to the writeback thread that have landed on disk.
    pub spills: u64,
    /// Blobs read back from the disk tier.
    pub disk_restores: u64,
    /// Writeback attempts that failed (blob stayed safely in RAM).
    pub spill_failures: u64,
}

impl TieredStore {
    /// Pure-RAM store: no budget, no disk tier. Matches the behaviour
    /// the bank had before tiering existed.
    pub fn in_ram() -> Self {
        Self::new(StoreConfig::default())
    }

    pub fn new(cfg: StoreConfig) -> Self {
        let mut tx = None;
        let mut done_rx = None;
        let mut writer = None;
        if let Some(dir) = &cfg.spill_dir {
            // Best-effort: create the tier directory and clear any
            // stale blobs a previous run left behind (session ids are
            // process-local, so leftovers can only alias wrongly).
            let _ = std::fs::create_dir_all(dir);
            if let Ok(entries) = std::fs::read_dir(dir) {
                for e in entries.flatten() {
                    let p = e.path();
                    let stale = p
                        .extension()
                        .map(|x| x == "blob" || x == "tmp")
                        .unwrap_or(false);
                    if stale {
                        let _ = std::fs::remove_file(&p);
                    }
                }
            }
            let (jtx, jrx) = channel::<WriteJob>();
            let (dtx, drx) = channel::<WriteDone>();
            writer = Some(thread::spawn(move || writeback_loop(jrx, dtx)));
            tx = Some(jtx);
            done_rx = Some(drx);
        }
        TieredStore {
            dir: cfg.spill_dir,
            budget: cfg.ram_budget,
            ram: HashMap::new(),
            ram_bytes_: 0,
            disk: HashMap::new(),
            disk_bytes_: 0,
            clock: 0,
            gen: 0,
            outstanding: 0,
            tx,
            done_rx,
            writer,
            shared: cfg.shared,
            spills: 0,
            disk_restores: 0,
            spill_failures: 0,
        }
    }

    fn blob_path(&self, id: u64) -> PathBuf {
        self.dir
            .as_ref()
            .expect("blob_path requires a spill dir")
            .join(format!("s{id:016x}.blob"))
    }

    /// Insert (or replace) a session blob. May queue cold blobs for
    /// disk writeback if the RAM tier is over budget.
    pub fn insert(&mut self, id: u64, blob: Vec<u8>) {
        self.drain_done(false);
        self.clock += 1;
        let len = blob.len();
        let old = self.ram.insert(
            id,
            RamEntry { bytes: Arc::new(blob), touch: self.clock, pending: None },
        );
        if let Some(old) = old {
            self.ram_bytes_ -= old.bytes.len();
        }
        // A fresh blob supersedes any disk copy of the same session.
        if let Some(len) = self.disk.remove(&id) {
            self.disk_bytes_ -= len;
            if let Some(sh) = &self.shared {
                sh.disk_bytes.fetch_sub(len, Ordering::Relaxed);
                sh.disk_sessions.fetch_sub(1, Ordering::Relaxed);
            }
            if self.dir.is_some() {
                let _ = std::fs::remove_file(self.blob_path(id));
            }
        }
        self.ram_bytes_ += len;
        self.enforce_budget();
    }

    /// Remove and return a session's blob, restoring from disk if it
    /// was spilled. `Ok(None)` means the store has no state for `id`.
    /// A corrupt or missing disk blob is a typed error; the entry is
    /// consumed either way so the session can start fresh.
    pub fn take(&mut self, id: u64) -> Result<Option<Vec<u8>>, SnapshotError> {
        self.drain_done(false);
        if let Some(entry) = self.ram.remove(&id) {
            self.ram_bytes_ -= entry.bytes.len();
            // If a writeback is in flight the Arc is shared; clone the
            // bytes and let apply_done garbage-collect the orphan file.
            let bytes = match Arc::try_unwrap(entry.bytes) {
                Ok(v) => v,
                Err(arc) => (*arc).clone(),
            };
            return Ok(Some(bytes));
        }
        if let Some(len) = self.disk.remove(&id) {
            self.disk_bytes_ -= len;
            if let Some(sh) = &self.shared {
                sh.disk_bytes.fetch_sub(len, Ordering::Relaxed);
                sh.disk_sessions.fetch_sub(1, Ordering::Relaxed);
            }
            let path = self.blob_path(id);
            let read = read_blob(&path);
            let _ = std::fs::remove_file(&path);
            let blob = read?;
            self.disk_restores += 1;
            if let Some(sh) = &self.shared {
                sh.disk_restores.fetch_add(1, Ordering::Relaxed);
            }
            return Ok(Some(blob));
        }
        Ok(None)
    }

    /// True if the store holds state for `id` in either tier.
    pub fn contains(&self, id: u64) -> bool {
        self.ram.contains_key(&id) || self.disk.contains_key(&id)
    }

    /// Sessions held in either tier.
    pub fn frozen_sessions(&self) -> usize {
        self.ram.len() + self.disk.len()
    }

    pub fn ram_sessions(&self) -> usize {
        self.ram.len()
    }

    pub fn disk_sessions(&self) -> usize {
        self.disk.len()
    }

    /// Bytes of blob payload resident in the RAM tier.
    pub fn ram_bytes(&self) -> usize {
        self.ram_bytes_
    }

    /// Bytes of blob payload on the disk tier (payload, not framing).
    pub fn disk_bytes(&self) -> usize {
        self.disk_bytes_
    }

    /// The RAM this store actually costs: RAM-tier blobs in full, plus
    /// one index entry per disk-tier session. This is the number the
    /// bank's memstate accounting reports.
    pub fn ram_footprint(&self) -> usize {
        self.ram_bytes_ + self.disk.len() * INDEX_ENTRY_BYTES
    }

    /// RAM cost attributable to one stored session, if stored.
    pub fn session_ram_bytes(&self, id: u64) -> Option<usize> {
        if let Some(e) = self.ram.get(&id) {
            return Some(e.bytes.len());
        }
        if self.disk.contains_key(&id) {
            return Some(INDEX_ENTRY_BYTES);
        }
        None
    }

    /// Block until every queued writeback has completed and its
    /// outcome is applied. Makes spill counters deterministic for
    /// tests and end-of-run reports.
    pub fn sync(&mut self) {
        self.drain_done(true);
    }

    fn enforce_budget(&mut self) {
        if self.tx.is_none() {
            return; // no disk tier: RAM tier is unbounded, as before
        }
        while self.ram_bytes_ > self.budget {
            // Coldest non-pending blob. `touch` values are unique
            // (monotone clock), so the choice is deterministic even
            // though HashMap iteration order is not.
            let victim = self
                .ram
                .iter()
                .filter(|(_, e)| e.pending.is_none())
                .min_by_key(|(_, e)| e.touch)
                .map(|(&id, _)| id);
            let Some(id) = victim else { break };
            self.gen += 1;
            let gen = self.gen;
            let entry = self.ram.get_mut(&id).unwrap();
            entry.pending = Some(gen);
            let job = WriteJob {
                id,
                gen,
                bytes: Arc::clone(&entry.bytes),
                path: self.blob_path(id),
            };
            self.outstanding += 1;
            if self.tx.as_ref().unwrap().send(job).is_err() {
                // Writer died; undo and stop trying.
                self.outstanding -= 1;
                self.ram.get_mut(&id).unwrap().pending = None;
                self.spill_failures += 1;
                break;
            }
            // The blob stays RAM-resident (and counted) until the
            // writeback completes; drain below may free it already.
            self.drain_done(false);
            if self.ram_bytes_ <= self.budget {
                break;
            }
            // All remaining blobs pending? Nothing more to queue now.
            if self.ram.values().all(|e| e.pending.is_some()) {
                break;
            }
        }
    }

    fn drain_done(&mut self, wait: bool) {
        let mut msgs = Vec::new();
        if let Some(rx) = &self.done_rx {
            while let Ok(m) = rx.try_recv() {
                msgs.push(m);
            }
            if wait {
                while self.outstanding > msgs.len() {
                    match rx.recv() {
                        Ok(m) => msgs.push(m),
                        Err(_) => break,
                    }
                }
            }
        }
        for m in msgs {
            self.outstanding -= 1;
            self.apply_done(m);
        }
    }

    fn apply_done(&mut self, m: WriteDone) {
        let live = self
            .ram
            .get(&m.id)
            .map(|e| e.pending == Some(m.gen))
            .unwrap_or(false);
        if !live {
            // The blob was taken or replaced while the write was in
            // flight. If no newer write for this id is queued and the
            // id has no disk index entry, the file is an orphan.
            let newer_queued = self
                .ram
                .get(&m.id)
                .map(|e| matches!(e.pending, Some(g) if g > m.gen))
                .unwrap_or(false);
            if m.ok && !newer_queued && !self.disk.contains_key(&m.id) {
                let _ = std::fs::remove_file(self.blob_path(m.id));
            }
            return;
        }
        if m.ok {
            let entry = self.ram.remove(&m.id).unwrap();
            self.ram_bytes_ -= entry.bytes.len();
            self.disk.insert(m.id, m.len);
            self.disk_bytes_ += m.len;
            self.spills += 1;
            if let Some(sh) = &self.shared {
                sh.spills.fetch_add(1, Ordering::Relaxed);
                sh.disk_bytes.fetch_add(m.len, Ordering::Relaxed);
                sh.disk_sessions.fetch_add(1, Ordering::Relaxed);
            }
        } else {
            // Disk refused the write; keep serving from RAM.
            self.spill_failures += 1;
            if let Some(e) = self.ram.get_mut(&m.id) {
                e.pending = None;
            }
        }
    }
}

impl Drop for TieredStore {
    fn drop(&mut self) {
        self.tx = None; // close the job channel so the writer exits
        self.drain_done(true);
        if let Some(w) = self.writer.take() {
            let _ = w.join();
        }
        // Indexed files stay on disk; a future run pointed at the same
        // dir clears them as stale on startup.
    }
}

fn writeback_loop(rx: Receiver<WriteJob>, done: Sender<WriteDone>) {
    for job in rx {
        let ok = write_blob(&job.path, &job.bytes).is_ok();
        let msg = WriteDone { id: job.id, gen: job.gen, len: job.bytes.len(), ok };
        if done.send(msg).is_err() {
            break;
        }
    }
}

/// Frame and write a blob: `SPILL_MAGIC | len u64 | fnv64 | payload`,
/// staged through a `.tmp` sibling and renamed so a crashed write
/// never leaves a half-frame under the final name.
fn write_blob(path: &Path, payload: &[u8]) -> std::io::Result<()> {
    let mut frame = Vec::with_capacity(FRAME_HEADER + payload.len());
    frame.extend_from_slice(&SPILL_MAGIC.to_le_bytes());
    frame.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    frame.extend_from_slice(&checksum(payload).to_le_bytes());
    frame.extend_from_slice(payload);
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &frame)?;
    std::fs::rename(&tmp, path)
}

/// Read a framed blob back, verifying magic, length, and checksum.
/// Every way a file can be wrong maps to a typed [`SnapshotError`].
pub fn read_blob(path: &Path) -> Result<Vec<u8>, SnapshotError> {
    let raw = std::fs::read(path)
        .map_err(|e| SnapshotError::Io(format!("{}: {e}", path.display())))?;
    if raw.len() < FRAME_HEADER {
        return Err(SnapshotError::Truncated {
            offset: 0,
            need: FRAME_HEADER,
            have: raw.len(),
        });
    }
    let magic = u32::from_le_bytes(raw[0..4].try_into().unwrap());
    if magic != SPILL_MAGIC {
        return Err(SnapshotError::BadMagic(magic));
    }
    let claimed = u64::from_le_bytes(raw[4..12].try_into().unwrap()) as usize;
    let remaining = raw.len() - FRAME_HEADER;
    if claimed != remaining {
        return Err(SnapshotError::BadLength { claimed, remaining });
    }
    let expect = u64::from_le_bytes(raw[12..20].try_into().unwrap());
    let payload = &raw[FRAME_HEADER..];
    let got = checksum(payload);
    if got != expect {
        return Err(SnapshotError::BadChecksum { expect, got });
    }
    Ok(payload.to_vec())
}

/// Stable key for a prompt prefix: FNV-1a over the little-endian token
/// bytes, mixed with the length so a prefix and its own prefix never
/// collide trivially.
pub fn prefix_key(tokens: &[u32]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325 ^ (tokens.len() as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// Point-in-time prefix-cache statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefixReport {
    pub hits: usize,
    pub misses: usize,
    pub bytes: usize,
    pub entries: usize,
}

/// Engine-wide copy-on-write prefix template cache.
///
/// Templates are immutable `Arc<[u8]>` packed-session blobs keyed by
/// prefix hash. Forking a session from a template is a plain snapshot
/// restore, so forks are bit-identical to having run the prefill —
/// the determinism argument lives in DESIGN.md "Memory hierarchy".
pub struct PrefixCache {
    enabled: bool,
    entries: Mutex<HashMap<u64, Arc<[u8]>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    bytes: AtomicUsize,
}

impl PrefixCache {
    pub fn new(enabled: bool) -> Self {
        PrefixCache {
            enabled,
            entries: Mutex::new(HashMap::new()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            bytes: AtomicUsize::new(0),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Look up a template, counting the hit or miss.
    pub fn lookup(&self, key: u64) -> Option<Arc<[u8]>> {
        if !self.enabled {
            return None;
        }
        let got = self.entries.lock().unwrap().get(&key).cloned();
        match &got {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        got
    }

    /// Freeze a packed-session blob as the template for `key`.
    /// Replacing an existing template (two sessions racing to build
    /// the same prefix produce identical bytes) keeps byte accounting
    /// straight.
    pub fn register(&self, key: u64, blob: Vec<u8>) {
        if !self.enabled {
            return;
        }
        let len = blob.len();
        let old = self
            .entries
            .lock()
            .unwrap()
            .insert(key, Arc::from(blob.into_boxed_slice()));
        if let Some(old) = old {
            self.bytes.fetch_sub(old.len(), Ordering::Relaxed);
        }
        self.bytes.fetch_add(len, Ordering::Relaxed);
    }

    pub fn stats(&self) -> PrefixReport {
        PrefixReport {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            entries: self.entries.lock().unwrap().len(),
        }
    }

    /// Join a metrics registry as render-time views (see
    /// [`TierStats::register_metrics`]; `register` is already taken by
    /// template registration above).
    pub fn register_metrics(self: &Arc<Self>, reg: &Registry) {
        let views: [(&str, fn(&PrefixCache) -> usize); 4] = [
            ("ovq_prefix_hits_total", |c| c.hits.load(Ordering::Relaxed)),
            ("ovq_prefix_misses_total", |c| c.misses.load(Ordering::Relaxed)),
            ("ovq_prefix_bytes", |c| c.bytes.load(Ordering::Relaxed)),
            ("ovq_prefix_entries", |c| c.entries.lock().unwrap().len()),
        ];
        for (name, read) in views {
            let me = Arc::clone(self);
            reg.gauge_fn(name, &[], move || read(&me) as f64);
        }
    }
}

/// Self-cleaning temp directory for tests and benches: unique path
/// under the system temp dir, removed (best-effort) on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(tag: &str) -> Self {
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let seq = SEQ.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "ovq-{tag}-{}-{seq}",
            std::process::id()
        ));
        let _ = std::fs::create_dir_all(&path);
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn blob(seed: u8, len: usize) -> Vec<u8> {
        (0..len).map(|i| seed.wrapping_add(i as u8)).collect()
    }

    fn store_with_budget(dir: &Path, budget: usize) -> TieredStore {
        TieredStore::new(StoreConfig {
            spill_dir: Some(dir.to_path_buf()),
            ram_budget: budget,
            shared: None,
        })
    }

    #[test]
    fn ram_only_store_never_touches_disk() {
        let mut s = TieredStore::in_ram();
        s.insert(1, blob(1, 100));
        s.insert(2, blob(2, 100));
        assert_eq!(s.ram_sessions(), 2);
        assert_eq!(s.disk_sessions(), 0);
        assert_eq!(s.ram_bytes(), 200);
        assert_eq!(s.ram_footprint(), 200);
        assert_eq!(s.take(1).unwrap(), Some(blob(1, 100)));
        assert_eq!(s.take(1).unwrap(), None);
        assert_eq!(s.ram_bytes(), 100);
    }

    #[test]
    fn over_budget_blobs_spill_coldest_first_and_restore_bit_identically() {
        let td = TempDir::new("spill-lru");
        let mut s = store_with_budget(td.path(), 250);
        s.insert(1, blob(1, 100)); // coldest
        s.insert(2, blob(2, 100));
        s.insert(3, blob(3, 100)); // over budget: 1 spills
        s.sync();
        assert_eq!(s.spills, 1);
        assert_eq!(s.ram_sessions(), 2);
        assert_eq!(s.disk_sessions(), 1);
        assert_eq!(s.ram_bytes(), 200);
        assert_eq!(s.disk_bytes(), 100);
        assert_eq!(s.ram_footprint(), 200 + INDEX_ENTRY_BYTES);
        assert_eq!(s.session_ram_bytes(1), Some(INDEX_ENTRY_BYTES));
        assert_eq!(s.session_ram_bytes(2), Some(100));
        // Restore from disk is bit-identical and consumes the entry.
        assert_eq!(s.take(1).unwrap(), Some(blob(1, 100)));
        assert_eq!(s.disk_restores, 1);
        assert_eq!(s.disk_sessions(), 0);
        assert!(!s.contains(1));
    }

    #[test]
    fn zero_budget_spills_everything() {
        let td = TempDir::new("spill-all");
        let mut s = store_with_budget(td.path(), 0);
        for id in 0..6u64 {
            s.insert(id, blob(id as u8, 64));
        }
        s.sync();
        assert_eq!(s.ram_bytes(), 0);
        assert_eq!(s.ram_sessions(), 0);
        assert_eq!(s.disk_sessions(), 6);
        assert_eq!(s.disk_bytes(), 6 * 64);
        assert_eq!(s.spills, 6);
        assert_eq!(s.ram_footprint(), 6 * INDEX_ENTRY_BYTES);
        for id in 0..6u64 {
            assert_eq!(s.take(id).unwrap(), Some(blob(id as u8, 64)), "session {id}");
        }
        assert_eq!(s.disk_restores, 6);
    }

    #[test]
    fn take_before_writeback_completes_returns_ram_bytes() {
        let td = TempDir::new("spill-race");
        let mut s = store_with_budget(td.path(), 0);
        // Insert queues a writeback immediately (budget 0); take right
        // away — whatever the writer thread is doing, we must get the
        // exact bytes back and the store must stay consistent.
        for round in 0..20u64 {
            s.insert(round, blob(round as u8, 256));
            assert_eq!(s.take(round).unwrap(), Some(blob(round as u8, 256)));
            assert!(!s.contains(round));
        }
        s.sync();
        assert_eq!(s.ram_bytes(), 0);
        assert_eq!(s.disk_bytes(), 0);
    }

    #[test]
    fn reinsert_supersedes_disk_copy() {
        let td = TempDir::new("spill-supersede");
        let mut s = store_with_budget(td.path(), 0);
        s.insert(7, blob(1, 128));
        s.sync();
        assert_eq!(s.disk_sessions(), 1);
        // Newer state for the same session replaces the spilled copy.
        s.insert(7, blob(9, 64));
        assert_eq!(s.take(7).unwrap(), Some(blob(9, 64)));
        s.sync();
        assert!(!s.contains(7));
    }

    #[test]
    fn frame_round_trips() {
        let td = TempDir::new("frame-rt");
        let p = td.path().join("x.blob");
        let payload = blob(42, 1000);
        write_blob(&p, &payload).unwrap();
        assert_eq!(read_blob(&p).unwrap(), payload);
    }

    #[test]
    fn corrupt_frames_are_typed_errors_never_panics() {
        let td = TempDir::new("frame-corrupt");
        let p = td.path().join("x.blob");
        let payload = blob(3, 200);
        write_blob(&p, &payload).unwrap();
        let good = std::fs::read(&p).unwrap();

        // Truncated below the header.
        std::fs::write(&p, &good[..10]).unwrap();
        assert!(matches!(read_blob(&p), Err(SnapshotError::Truncated { .. })));

        // Truncated payload: length claim no longer matches.
        std::fs::write(&p, &good[..good.len() - 5]).unwrap();
        assert!(matches!(read_blob(&p), Err(SnapshotError::BadLength { .. })));

        // Flipped payload bit: checksum catches it.
        let mut flipped = good.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x40;
        std::fs::write(&p, &flipped).unwrap();
        assert!(matches!(read_blob(&p), Err(SnapshotError::BadChecksum { .. })));

        // Wrong magic.
        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        std::fs::write(&p, &bad_magic).unwrap();
        assert!(matches!(read_blob(&p), Err(SnapshotError::BadMagic(_))));

        // Missing file entirely.
        std::fs::remove_file(&p).unwrap();
        assert!(matches!(read_blob(&p), Err(SnapshotError::Io(_))));
    }

    #[test]
    fn corrupt_disk_blob_is_a_clean_take_error_and_store_keeps_serving() {
        let td = TempDir::new("spill-corrupt");
        let mut s = store_with_budget(td.path(), 0);
        s.insert(1, blob(1, 300));
        s.insert(2, blob(2, 300));
        s.sync();
        assert_eq!(s.disk_sessions(), 2);
        // Corrupt session 1's file behind the store's back.
        let p1 = td.path().join(format!("s{:016x}.blob", 1u64));
        let mut raw = std::fs::read(&p1).unwrap();
        raw[FRAME_HEADER + 3] ^= 1;
        std::fs::write(&p1, &raw).unwrap();
        assert!(matches!(s.take(1), Err(SnapshotError::BadChecksum { .. })));
        // The bad entry is consumed; the store still serves others.
        assert!(!s.contains(1));
        assert_eq!(s.take(2).unwrap(), Some(blob(2, 300)));
    }

    #[test]
    fn fuzzed_frames_never_panic() {
        let td = TempDir::new("frame-fuzz");
        let p = td.path().join("f.blob");
        let payload = blob(17, 500);
        write_blob(&p, &payload).unwrap();
        let good = std::fs::read(&p).unwrap();
        let mut rng = Rng::new(0xF0CC);
        for _ in 0..200 {
            let mut bytes = good.clone();
            if rng.bool(0.5) {
                let cut = (rng.next_u64() as usize) % bytes.len();
                bytes.truncate(cut);
            } else {
                let at = (rng.next_u64() as usize) % bytes.len();
                bytes[at] ^= 1 << ((rng.next_u64() % 8) as u8);
            }
            std::fs::write(&p, &bytes).unwrap();
            match read_blob(&p) {
                Ok(got) => assert_eq!(got, payload), // flip in dead space? impossible here, but Ok must mean intact
                Err(_) => {}                         // typed error: fine
            }
        }
    }

    #[test]
    fn prefix_cache_counts_hits_misses_and_bytes() {
        let c = PrefixCache::new(true);
        let k = prefix_key(&[1, 2, 3, 4]);
        assert!(c.lookup(k).is_none());
        c.register(k, vec![0u8; 512]);
        let t = c.lookup(k).expect("registered template");
        assert_eq!(t.len(), 512);
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.bytes, st.entries), (1, 1, 512, 1));
        // Replacement keeps the byte gauge straight.
        c.register(k, vec![0u8; 128]);
        assert_eq!(c.stats().bytes, 128);
        // Disabled cache: no lookups, no registrations, no counting.
        let off = PrefixCache::new(false);
        assert!(off.lookup(k).is_none());
        off.register(k, vec![0u8; 64]);
        let st = off.stats();
        assert_eq!((st.hits, st.misses, st.bytes, st.entries), (0, 0, 0, 0));
    }

    #[test]
    fn prefix_keys_distinguish_prefixes() {
        let a = prefix_key(&[1, 2, 3]);
        assert_eq!(a, prefix_key(&[1, 2, 3]));
        assert_ne!(a, prefix_key(&[1, 2]));
        assert_ne!(a, prefix_key(&[1, 2, 4]));
        assert_ne!(a, prefix_key(&[]));
    }

    #[test]
    fn temp_dirs_clean_up_after_themselves() {
        let kept;
        {
            let td = TempDir::new("cleanup");
            kept = td.path().to_path_buf();
            std::fs::write(td.path().join("x"), b"y").unwrap();
            assert!(kept.exists());
        }
        assert!(!kept.exists());
    }

    #[test]
    fn stale_blobs_are_cleared_on_startup() {
        let td = TempDir::new("stale");
        std::fs::write(td.path().join("s00.blob"), b"junk").unwrap();
        std::fs::write(td.path().join("w.tmp"), b"junk").unwrap();
        let _s = store_with_budget(td.path(), 0);
        assert!(!td.path().join("s00.blob").exists());
        assert!(!td.path().join("w.tmp").exists());
    }
}
