//! Original VQ-attention state machine (Lingle 2023) — static pretrained
//! key dictionary, online value dictionary + counts. The Fig. 1 baseline.
//! Served through the unified [`SeqMixer`] interface.

use anyhow::Result;

use super::mixer::{dict_softmax_finish, dict_softmax_read, Scratch, SeqMixer};
use super::quant::{QuantMode, QuantTensor};
use super::snapshot;

#[derive(Debug, Clone)]
pub struct VqState {
    pub d: usize,
    pub n: usize,
    /// static pretrained key centroids [n, d] (unit-norm), stored in the
    /// tensor's quant format
    pub dk: QuantTensor,
    /// online value centroids [n, d]
    pub dv: QuantTensor,
    pub counts: Vec<f32>,
    pub beta: f32,
    /// tokens absorbed
    pub t: usize,
    /// merge staging row (transient, not snapshotted)
    row_v: Vec<f32>,
}

impl VqState {
    pub fn new(d: usize, dk: Vec<f32>) -> VqState {
        VqState::with_quant(d, dk, QuantMode::None)
    }

    /// Build with the dictionaries held in `quant` storage (the pretrained
    /// key dictionary is quantized once here, at load time).
    pub fn with_quant(d: usize, dk: Vec<f32>, quant: QuantMode) -> VqState {
        let n = dk.len() / d;
        VqState {
            d,
            n,
            dk: QuantTensor::from_f32(quant, n, d, &dk),
            dv: QuantTensor::new(quant, n, d),
            counts: vec![0.0; n],
            beta: 8.0,
            t: 0,
            row_v: vec![0.0; d],
        }
    }

    /// Storage format of the dictionaries.
    pub fn quant(&self) -> QuantMode {
        self.dk.mode()
    }

    /// Rebuild from a [`snapshot::save`] payload. The pretrained key
    /// dictionary travels with the blob — a restored session does not
    /// depend on the factory seed that originally built it — and thaws
    /// in its stored form (no requantization on restore).
    pub fn from_snapshot(r: &mut snapshot::Reader<'_>) -> Result<VqState> {
        let d = r.usize()?;
        let beta = r.f32()?;
        let t = r.usize()?;
        let dk = QuantTensor::load(r)?;
        let dv = QuantTensor::load(r)?;
        let counts = r.f32s()?;
        anyhow::ensure!(
            d > 0
                && d <= (1 << 16)
                && dk.d() == d
                && dv.d() == d
                && dv.rows() == dk.rows()
                && dv.mode() == dk.mode()
                && counts.len() == dk.rows(),
            "vq snapshot has inconsistent shapes"
        );
        let n = dk.rows();
        Ok(VqState {
            d,
            n,
            dk,
            dv,
            counts,
            beta,
            t,
            row_v: vec![0.0; d],
        })
    }

    /// Index of the key centroid with maximum inner product (blocked scan).
    pub fn nearest(&self, k: &[f32]) -> usize {
        let mut idx = [0usize];
        let mut sim = [f32::NEG_INFINITY];
        self.dk.nearest_rows(k, 1, &mut idx, &mut sim);
        idx[0]
    }
}

impl SeqMixer for VqState {
    fn kind_name(&self) -> &'static str {
        "vq"
    }

    fn d_in(&self) -> usize {
        self.d
    }

    fn d_out(&self) -> usize {
        self.d
    }

    fn tokens(&self) -> usize {
        self.t
    }

    fn state_bytes(&self) -> usize {
        self.dk.state_bytes() + self.dv.state_bytes() + self.counts.len() * 4
    }

    /// Sparse like OVQ: each token touches one value row + one count.
    fn update_bytes_per_chunk(&self, l: usize) -> usize {
        2 * l * self.d * 4
    }

    /// Absorb one (k, v): count-weighted mean into the assigned slot.
    /// The value row is staged through f32 (dequant, merge, requant) —
    /// a plain copy-in/copy-out for the f32 passthrough mode.
    fn write(&mut self, k: &[f32], v: &[f32]) {
        let s = self.nearest(k);
        let d = self.d;
        let c = self.counts[s];
        self.dv.read_row(s, &mut self.row_v);
        for j in 0..d {
            self.row_v[j] = (c * self.row_v[j] + v[j]) / (c + 1.0);
        }
        self.dv.write_row(s, &self.row_v);
        self.counts[s] = c + 1.0;
        self.t += 1;
    }

    /// Linear-form read (paper eq. 6): softmax(beta q Dk^T + log c) Dv.
    fn read(&self, q: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        dict_softmax_read(
            q,
            &self.dk,
            &self.dv,
            &self.counts,
            self.n,
            self.d,
            self.beta,
            &[],
            &[],
            0,
            out,
            scratch,
        );
    }

    /// Blocked prompt ingestion. The key dictionary is static, so the
    /// whole block's nearest-centroid assignments AND read logits
    /// (`q . Dk^T`) are computed up front with one tiled sweep each
    /// ([`kernels::nearest_rows`] / [`kernels::matmul_rows`]); the serial
    /// remainder is only the per-token O(d) value merge and the count-
    /// biased softmax, interleaved write-then-read so each read sees
    /// counts/values through token i exactly as serial decode does.
    fn process_prefill(
        &mut self,
        queries: &[f32],
        keys: &[f32],
        values: &[f32],
        out: &mut [f32],
        scratch: &mut Scratch,
    ) {
        let d = self.d;
        let n = self.n;
        let len = keys.len() / d;
        debug_assert_eq!(queries.len(), len * d);
        debug_assert_eq!(values.len(), len * d);
        debug_assert_eq!(out.len(), len * d);
        let Scratch { logits, weights, buf, idx } = scratch;
        if idx.len() < len {
            idx.resize(len, 0);
        }
        if buf.len() < len * n + len {
            buf.resize(len * n + len, 0.0);
        }
        let (sims, best) = buf.split_at_mut(len * n);
        let best = &mut best[..len];
        best.iter_mut().for_each(|b| *b = f32::NEG_INFINITY);
        self.dk.nearest_rows(keys, len, idx, best);
        self.dk.matmul_rows(queries, len, sims);
        if logits.len() < n {
            logits.resize(n, 0.0);
        }
        if weights.len() < n {
            weights.resize(n, 0.0);
        }
        for i in 0..len {
            // write: count-weighted mean into the preassigned slot (the
            // same arithmetic as `write`, minus the per-token search)
            let s = idx[i];
            let c = self.counts[s];
            self.dv.read_row(s, &mut self.row_v);
            for j in 0..d {
                self.row_v[j] = (c * self.row_v[j] + values[i * d + j]) / (c + 1.0);
            }
            self.dv.write_row(s, &self.row_v);
            self.counts[s] = c + 1.0;
            self.t += 1;
            // read: precomputed similarities, current counts/values
            logits[..n].copy_from_slice(&sims[i * n..(i + 1) * n]);
            dict_softmax_finish(
                &queries[i * d..(i + 1) * d],
                &self.dv,
                &self.counts,
                n,
                d,
                self.beta,
                &[],
                &[],
                0,
                logits,
                weights,
                &mut out[i * d..(i + 1) * d],
            );
        }
    }

    /// Writes-only prefill: the blocked nearest-centroid sweep plus the
    /// per-token value merges of [`Self::process_prefill`], with the
    /// count-biased softmax reads dropped. Assignments come from the
    /// static key dictionary, so skipping the reads cannot change them —
    /// the post-call state is bit-identical to the full prefill.
    fn prefill_writes(&mut self, keys: &[f32], values: &[f32], scratch: &mut Scratch) {
        let d = self.d;
        let len = keys.len() / d;
        debug_assert_eq!(values.len(), len * d);
        let Scratch { buf, idx, .. } = scratch;
        if idx.len() < len {
            idx.resize(len, 0);
        }
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        let best = &mut buf[..len];
        best.iter_mut().for_each(|b| *b = f32::NEG_INFINITY);
        self.dk.nearest_rows(keys, len, idx, best);
        for i in 0..len {
            let s = idx[i];
            let c = self.counts[s];
            self.dv.read_row(s, &mut self.row_v);
            for j in 0..d {
                self.row_v[j] = (c * self.row_v[j] + values[i * d + j]) / (c + 1.0);
            }
            self.dv.write_row(s, &self.row_v);
            self.counts[s] = c + 1.0;
            self.t += 1;
        }
    }

    fn snapshot(&self, w: &mut snapshot::Writer) {
        w.usize(self.d);
        w.f32(self.beta);
        w.usize(self.t);
        self.dk.save(w);
        self.dv.save(w);
        w.f32s(&self.counts);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn unit_dict(rng: &mut Rng, n: usize, d: usize) -> Vec<f32> {
        let mut dk = vec![0.0f32; n * d];
        for s in 0..n {
            let mut norm = 0.0;
            for j in 0..d {
                dk[s * d + j] = rng.normal() as f32;
                norm += dk[s * d + j] * dk[s * d + j];
            }
            let norm = norm.sqrt();
            for j in 0..d {
                dk[s * d + j] /= norm;
            }
        }
        dk
    }

    #[test]
    fn quantization_loses_offcluster_information() {
        // two keys assigned to the same centroid become indistinguishable —
        // the failure mode Fig. 1 demonstrates
        let mut rng = Rng::new(1);
        let dk = unit_dict(&mut rng, 2, 4);
        let mut st = VqState::new(4, dk.clone());
        let k = &dk[0..4];
        st.write(k, &[1.0; 4]);
        st.write(k, &[3.0; 4]); // same slot: value becomes the mean
        let mut out = [0.0; 4];
        let mut scratch = Scratch::new();
        st.beta = 100.0;
        st.read(k, &mut out, &mut scratch);
        for &o in &out {
            assert!((o - 2.0).abs() < 1e-3, "expected mean 2.0, got {o}");
        }
    }

    #[test]
    fn counts_bias_toward_heavy_clusters() {
        let mut rng = Rng::new(2);
        let dk = unit_dict(&mut rng, 2, 4);
        let mut st = VqState::new(4, dk.clone());
        // 9 writes to slot A with value 1, 1 write to slot B with value -1
        for _ in 0..9 {
            st.write(&dk[0..4].to_vec(), &[1.0; 4]);
        }
        st.write(&dk[4..8].to_vec(), &[-1.0; 4]);
        // an ambiguous query (sum of centroids) leans toward the heavy slot
        let q: Vec<f32> = (0..4).map(|j| dk[j] + dk[4 + j]).collect();
        st.beta = 0.0; // ignore similarity; counts only
        let mut out = [0.0; 4];
        let mut scratch = Scratch::new();
        st.read(&q, &mut out, &mut scratch);
        assert!(out[0] > 0.5, "count prior should dominate: {}", out[0]);
    }

    #[test]
    fn quantized_vq_snapshot_refreezes_bit_exactly() {
        let mut rng = Rng::new(9);
        let dk = unit_dict(&mut rng, 16, 64);
        let mut sizes = Vec::new();
        for quant in [QuantMode::None, QuantMode::F16, QuantMode::I8] {
            let mut st = VqState::with_quant(64, dk.clone(), quant);
            assert_eq!(st.quant(), quant);
            for _ in 0..32 {
                let k: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
                st.write(&k, &[0.5; 64]);
            }
            let mut w = snapshot::Writer::new();
            st.snapshot(&mut w);
            let blob = w.into_bytes();
            let mut r = snapshot::Reader::new(&blob);
            let back = VqState::from_snapshot(&mut r).unwrap();
            assert_eq!(r.remaining(), 0);
            let mut w2 = snapshot::Writer::new();
            back.snapshot(&mut w2);
            assert_eq!(w2.into_bytes(), blob, "{quant:?}: refreeze differs");
            sizes.push(st.state_bytes());
        }
        // d=64: (2*256n + 4n) / (2*68n + 4n) = 516/140 > 3.5
        assert!(sizes[0] as f64 / sizes[2] as f64 >= 3.5);
    }

    #[test]
    fn state_is_constant_size() {
        let mut rng = Rng::new(3);
        let dk = unit_dict(&mut rng, 8, 4);
        let mut st = VqState::new(4, dk);
        let b0 = st.state_bytes();
        for _ in 0..500 {
            let k: Vec<f32> = (0..4).map(|_| rng.normal() as f32).collect();
            st.write(&k, &[0.5; 4]);
        }
        assert_eq!(st.state_bytes(), b0);
        assert_eq!(st.tokens(), 500);
    }
}
