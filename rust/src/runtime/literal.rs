//! Literal construction/extraction helpers around the xla crate.

use anyhow::{bail, Context, Result};

/// The dtypes the artifact manifests use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
    Bf16,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            "bf16" => DType::Bf16,
            other => bail!("unknown dtype '{other}'"),
        })
    }

    pub fn element_type(&self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
            DType::U32 => xla::ElementType::U32,
            DType::Bf16 => xla::ElementType::Bf16,
        }
    }

    pub fn byte_width(&self) -> usize {
        match self {
            DType::Bf16 => 2,
            _ => 4,
        }
    }
}

fn bytes_of<T: Copy>(data: &[T]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(
            data.as_ptr() as *const u8,
            std::mem::size_of_val(data),
        )
    }
}

fn make_literal(ty: xla::ElementType, dims: &[usize], bytes: &[u8]) -> xla::Literal {
    xla::Literal::create_from_shape_and_untyped_data(ty, dims, bytes)
        .expect("shape/data size mismatch building literal")
}

/// f32 literal of the given shape from a flat row-major slice.
pub fn literal_f32(dims: &[usize], data: &[f32]) -> xla::Literal {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    make_literal(xla::ElementType::F32, dims, bytes_of(data))
}

pub fn literal_i32(dims: &[usize], data: &[i32]) -> xla::Literal {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    make_literal(xla::ElementType::S32, dims, bytes_of(data))
}

pub fn literal_u32(dims: &[usize], data: &[u32]) -> xla::Literal {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    make_literal(xla::ElementType::U32, dims, bytes_of(data))
}

pub fn scalar_i32(v: i32) -> xla::Literal {
    literal_i32(&[], &[v])
}

pub fn scalar_f32(v: f32) -> xla::Literal {
    literal_f32(&[], &[v])
}

pub fn to_vec_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().context("literal -> Vec<f32>")
}

pub fn to_vec_i32(l: &xla::Literal) -> Result<Vec<i32>> {
    l.to_vec::<i32>().context("literal -> Vec<i32>")
}

pub fn scalar_from(l: &xla::Literal) -> Result<f32> {
    Ok(to_vec_f32(l)?[0])
}

/// Raw bytes of a literal (for checkpointing).
pub fn literal_bytes(l: &xla::Literal) -> Result<Vec<u8>> {
    let n = l.size_bytes();
    let mut buf = vec![0u8; n];
    // copy_raw_to is typed; go through the element type
    match l.ty().context("literal element type")? {
        xla::ElementType::F32 => {
            let v = l.to_vec::<f32>()?;
            buf.copy_from_slice(bytes_of(&v));
        }
        xla::ElementType::S32 => {
            let v = l.to_vec::<i32>()?;
            buf.copy_from_slice(bytes_of(&v));
        }
        xla::ElementType::U32 => {
            let v = l.to_vec::<u32>()?;
            buf.copy_from_slice(bytes_of(&v));
        }
        other => bail!("unsupported checkpoint dtype {other:?}"),
    }
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let l = literal_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(l.element_count(), 6);
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn i32_roundtrip() {
        let l = literal_i32(&[4], &[-1, 0, 1, 2]);
        assert_eq!(to_vec_i32(&l).unwrap(), vec![-1, 0, 1, 2]);
    }

    #[test]
    fn scalar() {
        let l = scalar_i32(42);
        assert_eq!(l.element_count(), 1);
        assert_eq!(to_vec_i32(&l).unwrap(), vec![42]);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert!(DType::parse("f64").is_err());
    }
}
