//! Manifest parsing: the JSON contract emitted by python/compile/aot.py.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

use super::literal::DType;

/// One parameter leaf (name, shape, dtype) in flat order.
#[derive(Debug, Clone)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl LeafSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered program (init / train / eval_*).
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub file: String,
    pub batch: Option<usize>,
    pub seq: Option<usize>,
    pub n_dict: Option<usize>,
}

/// The full model manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub config: Json,
    pub params: Vec<LeafSpec>,
    pub programs: BTreeMap<String, ProgramSpec>,
}

impl Manifest {
    pub fn load(dir: &Path, model: &str) -> Result<Manifest> {
        let path = dir.join(format!("{model}.manifest.json"));
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} (run `make artifacts`)", path.display())
        })?;
        let j = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        Manifest::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let name = j
            .get("name")
            .and_then(|n| n.as_str())
            .context("manifest missing 'name'")?
            .to_string();
        let mut params = Vec::new();
        for p in j.get("params").and_then(|p| p.as_arr()).context("params")? {
            params.push(LeafSpec {
                name: p.get("name").and_then(|x| x.as_str()).context("leaf name")?.into(),
                shape: p
                    .get("shape")
                    .and_then(|x| x.as_arr())
                    .context("leaf shape")?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                dtype: DType::parse(
                    p.get("dtype").and_then(|x| x.as_str()).context("leaf dtype")?,
                )?,
            });
        }
        let mut programs = BTreeMap::new();
        for (k, v) in j.get("programs").and_then(|p| p.as_obj()).context("programs")? {
            programs.insert(
                k.clone(),
                ProgramSpec {
                    file: v.get("file").and_then(|x| x.as_str()).context("file")?.into(),
                    batch: v.get("batch").and_then(|x| x.as_usize()),
                    seq: v.get("seq").and_then(|x| x.as_usize()),
                    n_dict: v.get("n_dict").and_then(|x| x.as_usize()),
                },
            );
        }
        Ok(Manifest {
            name,
            config: j.get("config").cloned().unwrap_or(Json::Null),
            params,
            programs,
        })
    }

    pub fn param_count(&self) -> usize {
        self.params.len()
    }

    pub fn total_param_elems(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Config accessor with default.
    pub fn cfg_usize(&self, key: &str, default: usize) -> usize {
        self.config.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn cfg_f64(&self, key: &str, default: f64) -> f64 {
        self.config.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    /// The eval program names sorted by sequence length.
    pub fn eval_programs(&self) -> Vec<(&String, &ProgramSpec)> {
        let mut v: Vec<_> = self
            .programs
            .iter()
            .filter(|(k, _)| k.starts_with("eval"))
            .collect();
        v.sort_by_key(|(_, p)| (p.seq.unwrap_or(0), p.n_dict.unwrap_or(0)));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "m1",
      "config": {"dim": 64, "chunk": 32, "lr": 0.001},
      "params": [
        {"name": "['embed']", "shape": [256, 64], "dtype": "f32"},
        {"name": "['head']", "shape": [64, 256], "dtype": "f32"}
      ],
      "programs": {
        "init": {"file": "m1.init.hlo.txt"},
        "train": {"file": "m1.train.hlo.txt", "batch": 4, "seq": 128},
        "eval_256": {"file": "m1.eval_256.hlo.txt", "batch": 2, "seq": 256}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let j = json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&j).unwrap();
        assert_eq!(m.name, "m1");
        assert_eq!(m.param_count(), 2);
        assert_eq!(m.total_param_elems(), 256 * 64 * 2);
        assert_eq!(m.programs["train"].batch, Some(4));
        assert_eq!(m.cfg_usize("dim", 0), 64);
        assert!((m.cfg_f64("lr", 0.0) - 0.001).abs() < 1e-12);
        let evals = m.eval_programs();
        assert_eq!(evals.len(), 1);
        assert_eq!(evals[0].1.seq, Some(256));
    }
}
