//! L3 runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `*.manifest.json`) produced by `python/compile/aot.py` and executes them
//! on the PJRT CPU client via the `xla` crate. Python is never on this
//! path — the Rust binary is self-contained once artifacts exist.
//!
//! Program signature convention (must match python/compile/aot.py):
//!   init : (seed u32[2]) -> (P param leaves)
//!   train: (P params, P m, P v, step i32[], tokens i32[B,T],
//!           targets i32[B,T], mask f32[B,T])
//!          -> (P params', P m', P v', step', loss f32[], lr f32[])
//!   eval : (P params, tokens, targets, mask)
//!          -> (loss f32[], correct f32[B,T], nll f32[B,T])

pub mod literal;
pub mod manifest;
pub mod model;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

pub use literal::{literal_f32, literal_i32, literal_u32, to_vec_f32, DType};
pub use manifest::{LeafSpec, Manifest, ProgramSpec};
pub use model::{Model, TrainState};

/// A compiled, loaded HLO program.
pub struct Program {
    pub name: String,
    pub exe: xla::PjRtLoadedExecutable,
}

impl Program {
    /// Execute; the artifact convention is return_tuple=True, so the single
    /// output buffer is a tuple literal that we decompose into leaves.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing program {}", self.name))?;
        let mut out = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of {}", self.name))?;
        Ok(out.decompose_tuple()?)
    }

    /// Execute with borrowed inputs — avoids cloning long-lived argument
    /// literals (e.g. model parameters during an eval sweep). §Perf: this
    /// removed the per-eval-call host copy of every parameter leaf.
    pub fn run_refs(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<&xla::Literal>(args)
            .with_context(|| format!("executing program {}", self.name))?;
        let mut out = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching output of {}", self.name))?;
        Ok(out.decompose_tuple()?)
    }
}

/// The runtime: one PJRT client + a compiled-program cache.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<Program>>>,
}

impl Runtime {
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Resolve the artifacts directory: $OVQ_ARTIFACTS or ./artifacts.
    pub fn from_env() -> Result<Runtime> {
        let dir = std::env::var("OVQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Runtime::new(dir)
    }

    /// Load + compile an HLO-text artifact (cached by file name).
    pub fn load_program(&self, file: &str) -> Result<std::sync::Arc<Program>> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(p) = cache.get(file) {
                return Ok(p.clone());
            }
        }
        let path = self.artifacts_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let prog = std::sync::Arc::new(Program { name: file.to_string(), exe });
        self.cache
            .lock()
            .unwrap()
            .insert(file.to_string(), prog.clone());
        Ok(prog)
    }

    /// Load a model (manifest + lazily compiled programs).
    pub fn load_model(&self, name: &str) -> Result<Model<'_>> {
        let manifest = Manifest::load(&self.artifacts_dir, name)?;
        Ok(Model { rt: self, manifest })
    }

    /// All model names present in artifacts/index.json.
    pub fn list_models(&self) -> Result<Vec<String>> {
        let text = std::fs::read_to_string(self.artifacts_dir.join("index.json"))
            .context("reading artifacts/index.json (run `make artifacts`)")?;
        let j = crate::util::json::parse(&text).map_err(anyhow::Error::msg)?;
        Ok(j.get("models")
            .and_then(|m| m.as_arr())
            .map(|a| {
                a.iter()
                    .filter_map(|x| x.as_str().map(String::from))
                    .collect()
            })
            .unwrap_or_default())
    }
}
