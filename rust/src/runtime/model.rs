//! Model-level runtime API: typed wrappers over the init/train/eval
//! programs plus checkpointing of the training state.

use anyhow::{bail, Context, Result};

use super::literal::{literal_f32, literal_i32, literal_u32, scalar_from, scalar_i32, to_vec_f32};
use super::{Manifest, Runtime};

/// The carried training state: flat parameter/optimizer leaves as literals.
pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub m: Vec<xla::Literal>,
    pub v: Vec<xla::Literal>,
    pub step: i32,
}

/// Result of one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepMetrics {
    pub loss: f32,
    pub lr: f32,
    pub step: i32,
}

/// One eval batch result.
#[derive(Debug, Clone)]
pub struct EvalOut {
    pub loss: f32,
    /// per-position correctness [B*T] row-major, 0 where masked out
    pub correct: Vec<f32>,
    /// per-position masked nll [B*T]
    pub nll: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

pub struct Model<'rt> {
    pub rt: &'rt Runtime,
    pub manifest: Manifest,
}

impl<'rt> Model<'rt> {
    /// Run the init program: fresh params + zeroed optimizer state.
    pub fn init(&self, seed: u64) -> Result<TrainState> {
        let prog = self.program("init")?;
        let seed_lit = literal_u32(&[2], &[(seed >> 32) as u32, seed as u32]);
        let params = prog.run(&[seed_lit])?;
        if params.len() != self.manifest.param_count() {
            bail!(
                "init returned {} leaves, manifest says {}",
                params.len(),
                self.manifest.param_count()
            );
        }
        let mk_zeros = || -> Vec<xla::Literal> {
            self.manifest
                .params
                .iter()
                .map(|spec| literal_f32(&spec.shape, &vec![0.0; spec.numel()]))
                .collect()
        };
        Ok(TrainState { params, m: mk_zeros(), v: mk_zeros(), step: 0 })
    }

    fn program(&self, name: &str) -> Result<std::sync::Arc<super::Program>> {
        let spec = self
            .manifest
            .programs
            .get(name)
            .with_context(|| format!("model {} has no program '{name}'", self.manifest.name))?;
        self.rt.load_program(&spec.file)
    }

    /// Shapes the train program expects for (tokens, targets, mask).
    pub fn train_shape(&self) -> Result<(usize, usize)> {
        let spec = self.manifest.programs.get("train").context("no train program")?;
        Ok((spec.batch.context("batch")?, spec.seq.context("seq")?))
    }

    /// One training step. Consumes and replaces the state leaves.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        tokens: &[i32],
        targets: &[i32],
        mask: &[f32],
    ) -> Result<StepMetrics> {
        let (b, t) = self.train_shape()?;
        debug_assert_eq!(tokens.len(), b * t);
        let prog = self.program("train")?;
        let p = self.manifest.param_count();

        let mut args: Vec<xla::Literal> = Vec::with_capacity(3 * p + 4);
        args.extend(state.params.drain(..));
        args.extend(state.m.drain(..));
        args.extend(state.v.drain(..));
        args.push(scalar_i32(state.step));
        args.push(literal_i32(&[b, t], tokens));
        args.push(literal_i32(&[b, t], targets));
        args.push(literal_f32(&[b, t], mask));

        let mut out = prog.run(&args)?;
        if out.len() != 3 * p + 3 {
            bail!("train returned {} outputs, expected {}", out.len(), 3 * p + 3);
        }
        let lr = scalar_from(&out.pop().unwrap())?;
        let loss = scalar_from(&out.pop().unwrap())?;
        let step_lit = out.pop().unwrap();
        let step = step_lit.to_vec::<i32>()?[0];
        state.v = out.split_off(2 * p);
        state.m = out.split_off(p);
        state.params = out;
        state.step = step;
        Ok(StepMetrics { loss, lr, step })
    }

    /// Run an eval program by name (e.g. "eval_512" or "eval_512_N256").
    pub fn eval(
        &self,
        prog_name: &str,
        params: &[xla::Literal],
        tokens: &[i32],
        targets: &[i32],
        mask: &[f32],
    ) -> Result<EvalOut> {
        let spec = self
            .manifest
            .programs
            .get(prog_name)
            .with_context(|| format!("no program '{prog_name}'"))?;
        let (b, t) = (spec.batch.context("batch")?, spec.seq.context("seq")?);
        debug_assert_eq!(tokens.len(), b * t);
        let prog = self.program(prog_name)?;

        // Borrow the parameter literals directly (no host copy) and only
        // materialize the three small batch inputs.
        let tok_lit = literal_i32(&[b, t], tokens);
        let tgt_lit = literal_i32(&[b, t], targets);
        let msk_lit = literal_f32(&[b, t], mask);
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(params.len() + 3);
        args.extend(params.iter());
        args.push(&tok_lit);
        args.push(&tgt_lit);
        args.push(&msk_lit);

        let out = prog.run_refs(&args)?;
        if out.len() != 3 {
            bail!("eval returned {} outputs, expected 3", out.len());
        }
        Ok(EvalOut {
            loss: scalar_from(&out[0])?,
            correct: to_vec_f32(&out[1])?,
            nll: to_vec_f32(&out[2])?,
            batch: b,
            seq: t,
        })
    }

    // ------------------------------------------------------- checkpointing

    /// Binary checkpoint: magic, step, leaf count, then per leaf
    /// (name len, name, byte len, raw f32 bytes) for params/m/v.
    pub fn save_checkpoint(&self, state: &TrainState, path: &str) -> Result<()> {
        use std::io::Write;
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(b"OVQCKPT1")?;
        w.write_all(&state.step.to_le_bytes())?;
        w.write_all(&(self.manifest.param_count() as u32).to_le_bytes())?;
        for group in [&state.params, &state.m, &state.v] {
            for (lit, spec) in group.iter().zip(&self.manifest.params) {
                let data = to_vec_f32(lit)?;
                w.write_all(&(spec.name.len() as u32).to_le_bytes())?;
                w.write_all(spec.name.as_bytes())?;
                w.write_all(&(data.len() as u64).to_le_bytes())?;
                let bytes = unsafe {
                    std::slice::from_raw_parts(
                        data.as_ptr() as *const u8,
                        data.len() * 4,
                    )
                };
                w.write_all(bytes)?;
            }
        }
        Ok(())
    }

    pub fn load_checkpoint(&self, path: &str) -> Result<TrainState> {
        use std::io::Read;
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != b"OVQCKPT1" {
            bail!("bad checkpoint magic");
        }
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        let step = i32::from_le_bytes(b4);
        r.read_exact(&mut b4)?;
        let count = u32::from_le_bytes(b4) as usize;
        if count != self.manifest.param_count() {
            bail!("checkpoint leaf count {count} != manifest {}", self.manifest.param_count());
        }
        let mut groups = Vec::new();
        for _ in 0..3 {
            let mut leaves = Vec::with_capacity(count);
            for spec in &self.manifest.params {
                r.read_exact(&mut b4)?;
                let nlen = u32::from_le_bytes(b4) as usize;
                let mut name = vec![0u8; nlen];
                r.read_exact(&mut name)?;
                let name = String::from_utf8_lossy(&name).to_string();
                if name != spec.name {
                    bail!("checkpoint leaf '{name}' != manifest '{}'", spec.name);
                }
                let mut b8 = [0u8; 8];
                r.read_exact(&mut b8)?;
                let n = u64::from_le_bytes(b8) as usize;
                if n != spec.numel() {
                    bail!("checkpoint leaf '{name}' has {n} elems, expected {}", spec.numel());
                }
                let mut data = vec![0f32; n];
                let bytes = unsafe {
                    std::slice::from_raw_parts_mut(
                        data.as_mut_ptr() as *mut u8,
                        n * 4,
                    )
                };
                r.read_exact(bytes)?;
                leaves.push(literal_f32(&spec.shape, &data));
            }
            groups.push(leaves);
        }
        let v = groups.pop().unwrap();
        let m = groups.pop().unwrap();
        let params = groups.pop().unwrap();
        Ok(TrainState { params, m, v, step })
    }
}
