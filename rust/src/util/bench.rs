//! Custom micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by the `benches/*` targets (all declared with `harness = false`):
//! warmup, fixed-duration timed phase, mean/p50/p99 and throughput
//! reporting, plus a machine-readable one-line summary for EXPERIMENTS.md.

use std::time::{Duration, Instant};

use super::stats;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub throughput: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn report(&self) {
        let thr = match self.throughput {
            Some((v, unit)) => format!("  {v:12.1} {unit}"),
            None => String::new(),
        };
        println!(
            "bench {:40} {:10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}{}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            thr
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            min_iters: 5,
            max_iters: 1_000_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_iters: 3,
            max_iters: 100_000,
        }
    }

    /// Run `f` repeatedly; `f` returns a value that is black-boxed.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // warmup
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            black_box(f());
        }
        // measure
        let mut samples = Vec::new();
        let t1 = Instant::now();
        while (t1.elapsed() < self.measure || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let s = Instant::now();
            black_box(f());
            samples.push(s.elapsed().as_nanos() as f64);
        }
        let r = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_ns: stats::mean(&samples),
            p50_ns: stats::percentile(&samples, 50.0),
            p99_ns: stats::percentile(&samples, 99.0),
            throughput: None,
        };
        r.report();
        r
    }

    /// Like run, but reports `units_per_iter / time` as throughput.
    pub fn run_throughput<T, F: FnMut() -> T>(
        &self,
        name: &str,
        units_per_iter: f64,
        unit: &'static str,
        f: F,
    ) -> BenchResult {
        let mut r = self.run(name, f);
        r.throughput = Some((units_per_iter / (r.mean_ns / 1e9), unit));
        r.report();
        r
    }
}

/// Prevent the optimizer from eliding benchmark bodies.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_iters: 3,
            max_iters: 10_000,
        };
        let r = b.run("noop-sum", || (0..100u64).sum::<u64>());
        assert!(r.iters >= 3);
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn formats_ns() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 us");
        assert_eq!(fmt_ns(2_500_000.0), "2.50 ms");
    }
}
