//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `ovq <subcommand> [positional...] [--key value | --flag]`.
//! Numeric accessors return `anyhow` errors with a usage hint instead of
//! panicking, so a typo'd flag surfaces as a clean CLI error, not a
//! backtrace.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                a.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = key.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    a.options
                        .insert(key.to_string(), it.next().unwrap().clone());
                } else {
                    a.flags.push(key.to_string());
                }
            } else {
                a.positional.push(arg.clone());
            }
        }
        a
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    /// Shared parse-or-default core for the numeric accessors.
    fn parsed<T: std::str::FromStr>(&self, key: &str, default: T, what: &str) -> Result<T> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(x) => Ok(x),
                Err(_) => bail!(
                    "--{key} expects {what}, got '{v}' \
                     (usage: --{key} <{what}> or --{key}=<{what}>; \
                     run `ovq` with no arguments for the full usage)"
                ),
            },
        }
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> Result<usize> {
        self.parsed(key, default, "an integer")
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> Result<u64> {
        self.parsed(key, default, "an integer")
    }

    /// Port-sized integers (`--port`): parse failures and out-of-range
    /// values both surface as the usual usage-hint error.
    pub fn opt_u16(&self, key: &str, default: u16) -> Result<u16> {
        self.parsed(key, default, "a port number")
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> Result<f64> {
        self.parsed(key, default, "a number")
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // note: a bare --flag consumes the next token as its value unless
        // that token is another --option; positionals go before flags.
        let a = Args::parse(&s(&["train", "taskname", "--model",
                                 "icr-sw-ovq", "--steps=100", "--quick"]));
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.opt("model"), Some("icr-sw-ovq"));
        assert_eq!(a.opt_usize("steps", 0).unwrap(), 100);
        assert!(a.has_flag("quick"));
        assert_eq!(a.positional, vec!["taskname"]);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&s(&["x"]));
        assert_eq!(a.opt_or("missing", "d"), "d");
        assert_eq!(a.opt_usize("n", 7).unwrap(), 7);
        assert!(!a.has_flag("q"));
    }

    #[test]
    fn bad_numeric_values_error_with_a_usage_hint() {
        let a = Args::parse(&s(&["serve", "--threads=lots", "--seed", "soon", "--lr", "fast"]));
        let e = a.opt_usize("threads", 1).unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("--threads expects an integer"), "{msg}");
        assert!(msg.contains("usage"), "hint missing: {msg}");
        assert!(a.opt_u64("seed", 0).is_err());
        assert!(a.opt_f64("lr", 0.1).is_err());
        // untouched keys still fall back cleanly
        assert_eq!(a.opt_f64("momentum", 0.9).unwrap(), 0.9);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&s(&["exp", "f4", "--quick"]));
        assert_eq!(a.subcommand, "exp");
        assert_eq!(a.positional, vec!["f4"]);
        assert!(a.has_flag("quick"));
    }
}
