//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `ovq <subcommand> [positional...] [--key value | --flag]`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                a.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = key.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    a.options
                        .insert(key.to_string(), it.next().unwrap().clone());
                } else {
                    a.flags.push(key.to_string());
                }
            } else {
                a.positional.push(arg.clone());
            }
        }
        a
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> u64 {
        self.opt(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.opt(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        // note: a bare --flag consumes the next token as its value unless
        // that token is another --option; positionals go before flags.
        let a = Args::parse(&s(&["train", "taskname", "--model",
                                 "icr-sw-ovq", "--steps=100", "--quick"]));
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.opt("model"), Some("icr-sw-ovq"));
        assert_eq!(a.opt_usize("steps", 0), 100);
        assert!(a.has_flag("quick"));
        assert_eq!(a.positional, vec!["taskname"]);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&s(&["x"]));
        assert_eq!(a.opt_or("missing", "d"), "d");
        assert_eq!(a.opt_usize("n", 7), 7);
        assert!(!a.has_flag("q"));
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&s(&["exp", "f4", "--quick"]));
        assert_eq!(a.subcommand, "exp");
        assert_eq!(a.positional, vec!["f4"]);
        assert!(a.has_flag("quick"));
    }
}
