//! CSV writer for experiment outputs (each figure/table driver emits a CSV
//! that EXPERIMENTS.md references).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<CsvWriter> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w, cols: header.len() })
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        assert_eq!(fields.len(), self.cols, "csv row width mismatch");
        let escaped: Vec<String> = fields
            .iter()
            .map(|f| {
                if f.contains(',') || f.contains('"') || f.contains('\n') {
                    format!("\"{}\"", f.replace('"', "\"\""))
                } else {
                    f.clone()
                }
            })
            .collect();
        writeln!(self.w, "{}", escaped.join(","))
    }

    pub fn rowf(&mut self, fields: &[f64]) -> std::io::Result<()> {
        self.row(&fields.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_escaped_rows() {
        let path = std::env::temp_dir().join("ovq_csv_test.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["x,y".into(), "plain".into()]).unwrap();
            w.rowf(&[1.5, 2.0]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n\"x,y\",plain\n1.5,2\n");
        std::fs::remove_file(&path).ok();
    }
}
