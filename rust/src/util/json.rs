//! Minimal JSON parser + writer (serde is unavailable offline — see
//! DESIGN.md dependency-constraint table). Supports the full JSON grammar
//! we emit from python: objects, arrays, strings (with escapes), numbers,
//! booleans, null. Numbers are kept as f64 with an i64 fast path.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Exact non-negative integer access: `Some` only when the number is
    /// integral and fits `u64` (the HTTP edge validates ids/counts here).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path access: `j.at(&["programs", "train", "file"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    /// Object builder: `Json::obj([("a", Json::Num(1.0)), ...])`. Saves
    /// the `BTreeMap` + `.to_string()` boilerplate at response-assembly
    /// sites (the HTTP edge builds every body this way).
    pub fn obj<K, I>(pairs: I) -> Json
    where
        K: Into<String>,
        I: IntoIterator<Item = (K, Json)>,
    {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }
}

// ------------------------------------------------------------------ parser

pub fn parse(s: &str) -> Result<Json, String> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E'
                || c == b'+' || c == b'-'
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{txt}': {e}"))
    }
}

// ------------------------------------------------------------------ writer

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_types() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\nthere\"").unwrap(),
                   Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a":[1,2,{"b":null}],"c":{"d":false}}"#).unwrap();
        assert_eq!(j.at(&["c", "d"]).unwrap(), &Json::Bool(false));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"m","params":[{"shape":[2,3],"dtype":"f32"}],"x":1.5}"#;
        let j = parse(src).unwrap();
        let j2 = parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn u64_access_is_exact() {
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        assert_eq!(parse("\"42\"").unwrap().as_u64(), None);
    }

    #[test]
    fn obj_builder_matches_literal_form() {
        let j = Json::obj([("b", Json::Bool(true)), ("a", Json::Num(1.0))]);
        assert_eq!(j.to_string(), r#"{"a":1,"b":true}"#);
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn errors_are_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
    }
}
