//! Minimal leveled logger with wall-clock timestamps relative to startup.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(1); // 0=error 1=info 2=debug
static START: OnceLock<Instant> = OnceLock::new();

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(level: u8, tag: &str, msg: &str) {
    if level <= LEVEL.load(Ordering::Relaxed) {
        eprintln!("[{:9.3}s] {:5} {}", elapsed(), tag, msg);
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log(1, "INFO", &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log(2, "DEBUG", &format!($($arg)*)) };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log(0, "ERROR", &format!($($arg)*)) };
}
