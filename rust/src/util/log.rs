//! Minimal leveled logger with wall-clock timestamps relative to startup.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(1); // 0=error 1=info 2=debug
static START: OnceLock<Instant> = OnceLock::new();

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

/// Pin the timestamp epoch to *now*. Call this first thing in main (and
/// server startup) so log timestamps measure from process start — without
/// it, `START` is lazily pinned by the first log line and every timestamp
/// is skewed by however long startup took before that line.
pub fn init() {
    let _ = START.set(Instant::now());
}

pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(level: u8, tag: &str, msg: &str) {
    if level <= LEVEL.load(Ordering::Relaxed) {
        eprintln!("[{:9.3}s] {:5} {}", elapsed(), tag, msg);
    }
}

/// Like [`log`], with the request id attached as a structured `req=` field
/// — the serving edge's per-request log form. Suppressed (falls back to
/// the plain form without the id) when observability is `--obs off`.
pub fn log_req(level: u8, tag: &str, req: &str, msg: &str) {
    if level <= LEVEL.load(Ordering::Relaxed) {
        if crate::util::obs::level() == crate::util::obs::ObsLevel::Off {
            eprintln!("[{:9.3}s] {:5} {}", elapsed(), tag, msg);
        } else {
            eprintln!("[{:9.3}s] {:5} req={} {}", elapsed(), tag, req, msg);
        }
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log(1, "INFO", &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log(2, "DEBUG", &format!($($arg)*)) };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log(0, "ERROR", &format!($($arg)*)) };
}

/// `info!` with a leading request-id field: `info_req!(rid, "fmt", ...)`.
#[macro_export]
macro_rules! info_req {
    ($req:expr, $($arg:tt)*) => {
        $crate::util::log::log_req(1, "INFO", $req, &format!($($arg)*))
    };
}

/// `debug!` with a leading request-id field: `debug_req!(rid, "fmt", ...)`.
#[macro_export]
macro_rules! debug_req {
    ($req:expr, $($arg:tt)*) => {
        $crate::util::log::log_req(2, "DEBUG", $req, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_is_monotonic_from_init() {
        init(); // idempotent — a second init elsewhere is a no-op
        let a = elapsed();
        let b = elapsed();
        assert!(a >= 0.0);
        assert!(b >= a);
        init();
        assert!(elapsed() >= b);
    }
}
