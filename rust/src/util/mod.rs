//! Zero-dependency utilities (serde/clap/criterion/proptest/rand are not
//! available offline; DESIGN.md documents each substitution).

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod log;
pub mod obs;
pub mod prop;
pub mod rng;
pub mod stats;
