//! Dependency-light observability: a lock-free metrics registry (atomic
//! counters, gauges, and log-bucketed latency histograms), bounded
//! per-shard trace-span rings, and Prometheus text exposition — std only.
//!
//! Cost contract (see DESIGN.md "Observability"): recording a histogram
//! sample is a 6-step binary search over 63 static bucket bounds plus
//! three relaxed atomic adds; recording a trace span is one push into a
//! bounded, shard-local ring behind an uncontended mutex, and happens
//! only at [`ObsLevel::Trace`]. Spans record wall-clock time but never
//! feed computation, so the engine's bit-identity goldens hold at every
//! `--obs` level.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicI64, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

// ---------------------------------------------------------------------------
// observability level
// ---------------------------------------------------------------------------

/// How much the serving stack records: `Off` disables spans and the
/// request-id log field, `Metrics` (default) keeps the registry live,
/// `Trace` additionally captures per-stage spans into the trace rings.
/// Histogram/counter recording is always on — the registry is the source
/// of truth for `/v1/stats` and the end-of-run reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObsLevel {
    Off,
    Metrics,
    Trace,
}

static LEVEL: AtomicU8 = AtomicU8::new(1); // 0=off 1=metrics 2=trace

impl ObsLevel {
    pub fn parse(s: &str) -> Result<ObsLevel, String> {
        match s {
            "off" => Ok(ObsLevel::Off),
            "metrics" => Ok(ObsLevel::Metrics),
            "trace" => Ok(ObsLevel::Trace),
            other => Err(format!("unknown obs level '{other}' (want off|metrics|trace)")),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ObsLevel::Off => "off",
            ObsLevel::Metrics => "metrics",
            ObsLevel::Trace => "trace",
        }
    }
}

pub fn set_level(level: ObsLevel) {
    let v = match level {
        ObsLevel::Off => 0,
        ObsLevel::Metrics => 1,
        ObsLevel::Trace => 2,
    };
    LEVEL.store(v, Ordering::Relaxed);
}

pub fn level() -> ObsLevel {
    match LEVEL.load(Ordering::Relaxed) {
        0 => ObsLevel::Off,
        2 => ObsLevel::Trace,
        _ => ObsLevel::Metrics,
    }
}

/// One relaxed load — the decode hot path's only obligation when spans
/// are not being captured.
#[inline]
pub fn trace_enabled() -> bool {
    LEVEL.load(Ordering::Relaxed) == 2
}

/// Serializes tests that flip the global obs level: the level is
/// process-wide state, so concurrent set/restore pairs in parallel unit
/// tests would make span-capture assertions flaky.
#[cfg(test)]
pub(crate) fn test_level_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// request ids
// ---------------------------------------------------------------------------

static NEXT_REQ: AtomicU64 = AtomicU64::new(1);

/// Mint a fresh process-unique request id (the HTTP edge echoes it as
/// `x-request-id` in hex).
pub fn next_request_id() -> u64 {
    NEXT_REQ.fetch_add(1, Ordering::Relaxed)
}

/// Fold a client-supplied `x-request-id` string to the u64 the trace
/// spans carry (FNV-1a; the original string is still echoed verbatim).
pub fn hash_request_id(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// counters and gauges
// ---------------------------------------------------------------------------

/// Monotone event counter; clone shares the underlying atomic.
#[derive(Debug, Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed level (queue depth, inflight requests).
#[derive(Debug, Clone, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn dec(&self) {
        self.add(-1);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// log-bucketed histograms
// ---------------------------------------------------------------------------

/// Bucket count: 63 finite log-spaced upper bounds plus one overflow
/// bucket. Bounds grow by 2^(2/3) from 1, so values from 1 ns to ~48 min
/// land in a finite bucket — bounded memory for any latency the serving
/// path can plausibly produce, with ~26% worst-case relative error.
pub const HIST_BUCKETS: usize = 64;

/// The shared finite upper bounds (`le` values); bucket `i` holds
/// `bounds[i-1] < v <= bounds[i]`, bucket 63 is the +Inf overflow.
pub fn bucket_bounds() -> &'static [f64; HIST_BUCKETS - 1] {
    static BOUNDS: OnceLock<[f64; HIST_BUCKETS - 1]> = OnceLock::new();
    BOUNDS.get_or_init(|| {
        let mut b = [0.0; HIST_BUCKETS - 1];
        for (i, slot) in b.iter_mut().enumerate() {
            *slot = 2f64.powf(i as f64 * 2.0 / 3.0);
        }
        b
    })
}

/// Index of the bucket containing `v` (binary search on the monotone
/// predicate, so the bucket's bounds always contain the value exactly).
pub fn bucket_index(v: f64) -> usize {
    bucket_bounds().partition_point(|&b| b < v)
}

#[derive(Debug)]
struct HistInner {
    counts: [AtomicU64; HIST_BUCKETS],
    /// Accumulated in integer units (the histograms store nanoseconds),
    /// so the sum needs no CAS loop.
    sum: AtomicU64,
    count: AtomicU64,
}

/// Fixed-size log-bucketed histogram; p50/p99 come from bounded memory
/// instead of an unbounded `Vec<f64>`. Clone shares the buckets.
#[derive(Debug, Clone)]
pub struct Histogram(Arc<HistInner>);

impl Default for Histogram {
    fn default() -> Self {
        Histogram(Arc::new(HistInner {
            counts: [const { AtomicU64::new(0) }; HIST_BUCKETS],
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }))
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Record one sample. Non-finite or negative inputs clamp to zero
    /// (bucket 0) rather than poisoning the distribution.
    pub fn record(&self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        let i = bucket_index(v);
        self.0.counts[i].fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v as u64, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> HistSnapshot {
        let counts: Vec<u64> = self
            .0
            .counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        HistSnapshot {
            counts,
            sum: self.0.sum.load(Ordering::Relaxed) as f64,
            count: self.0.count.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of a histogram; merges across shards/label sets
/// are exact because every histogram shares the same bucket bounds.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistSnapshot {
    pub counts: Vec<u64>,
    pub sum: f64,
    pub count: u64,
}

impl HistSnapshot {
    pub fn merge(&mut self, other: &HistSnapshot) {
        if self.counts.is_empty() {
            self.counts = vec![0; HIST_BUCKETS];
        }
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.sum += other.sum;
        self.count += other.count;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Percentile with linear interpolation inside the winning bucket.
    /// Uses the same rank convention as `stats::percentile` (rank =
    /// p/100 * (n-1)), so the result is within one bucket-width of the
    /// exact sample percentile.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let bounds = bucket_bounds();
        let rank = (p / 100.0).clamp(0.0, 1.0) * (self.count - 1) as f64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let hi = seen + c;
            // rank falls inside this bucket's run of samples
            if rank < hi as f64 || i == self.counts.len() - 1 {
                let lower = if i == 0 { 0.0 } else { bounds[i - 1] };
                let upper = if i < bounds.len() {
                    bounds[i]
                } else {
                    // overflow bucket: report its lower bound rather
                    // than inventing an upper one
                    return bounds[bounds.len() - 1];
                };
                let w = ((rank - seen as f64 + 1.0) / c as f64).clamp(0.0, 1.0);
                return lower + (upper - lower) * w;
            }
            seen = hi;
        }
        bounds[bounds.len() - 1]
    }
}

// ---------------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------------

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    /// Render-time view over state owned elsewhere (e.g. `TierStats`
    /// atomics) — lets existing report structs join the registry without
    /// duplicating their storage.
    GaugeFn(Arc<dyn Fn() -> f64 + Send + Sync>),
    Histogram(Histogram),
}

impl Metric {
    fn type_name(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) | Metric::GaugeFn(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

struct Entry {
    name: String,
    labels: Vec<(String, String)>,
    metric: Metric,
}

/// Metric registry: typed handles registered by name + labels. Handles
/// are lock-free atomics; the mutex guards only registration and
/// render-time iteration, never the record path.
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn owned(labels: &[(&str, &str)]) -> Vec<(String, String)> {
        labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect()
    }

    /// Register (or fetch the existing handle for) a counter.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        let want = Self::owned(labels);
        let mut es = self.entries.lock().unwrap();
        for e in es.iter() {
            if e.name == name && e.labels == want {
                if let Metric::Counter(c) = &e.metric {
                    return c.clone();
                }
            }
        }
        let c = Counter::default();
        es.push(Entry { name: name.to_string(), labels: want, metric: Metric::Counter(c.clone()) });
        c
    }

    /// Register (or fetch the existing handle for) a gauge.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        let want = Self::owned(labels);
        let mut es = self.entries.lock().unwrap();
        for e in es.iter() {
            if e.name == name && e.labels == want {
                if let Metric::Gauge(g) = &e.metric {
                    return g.clone();
                }
            }
        }
        let g = Gauge::default();
        es.push(Entry { name: name.to_string(), labels: want, metric: Metric::Gauge(g.clone()) });
        g
    }

    /// Register a render-time gauge view (idempotent by name+labels: a
    /// second registration replaces the first closure).
    pub fn gauge_fn<F>(&self, name: &str, labels: &[(&str, &str)], f: F)
    where
        F: Fn() -> f64 + Send + Sync + 'static,
    {
        let want = Self::owned(labels);
        let mut es = self.entries.lock().unwrap();
        for e in es.iter_mut() {
            if e.name == name && e.labels == want {
                if matches!(e.metric, Metric::GaugeFn(_)) {
                    e.metric = Metric::GaugeFn(Arc::new(f));
                    return;
                }
            }
        }
        es.push(Entry {
            name: name.to_string(),
            labels: want,
            metric: Metric::GaugeFn(Arc::new(f)),
        });
    }

    /// Register (or fetch the existing handle for) a histogram.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Histogram {
        let want = Self::owned(labels);
        let mut es = self.entries.lock().unwrap();
        for e in es.iter() {
            if e.name == name && e.labels == want {
                if let Metric::Histogram(h) = &e.metric {
                    return h.clone();
                }
            }
        }
        let h = Histogram::new();
        es.push(Entry {
            name: name.to_string(),
            labels: want,
            metric: Metric::Histogram(h.clone()),
        });
        h
    }

    /// Merged snapshot of every histogram registered under `name`
    /// (across all label sets) — the percentile source for the reports.
    pub fn histogram_snapshot(&self, name: &str) -> HistSnapshot {
        let es = self.entries.lock().unwrap();
        let mut snap = HistSnapshot::default();
        for e in es.iter() {
            if e.name == name {
                if let Metric::Histogram(h) = &e.metric {
                    snap.merge(&h.snapshot());
                }
            }
        }
        snap
    }

    fn fmt_labels(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
        let mut parts: Vec<String> = labels
            .iter()
            .map(|(k, v)| format!("{k}=\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\"")))
            .collect();
        if let Some((k, v)) = extra {
            parts.push(format!("{k}=\"{v}\""));
        }
        if parts.is_empty() {
            String::new()
        } else {
            format!("{{{}}}", parts.join(","))
        }
    }

    /// Render the whole registry in Prometheus text exposition format
    /// (one `# TYPE` line per metric name, `_bucket`/`_sum`/`_count`
    /// series for histograms).
    pub fn render_prometheus(&self) -> String {
        let es = self.entries.lock().unwrap();
        let mut out = String::new();
        let mut typed: Vec<&str> = Vec::new();
        for e in es.iter() {
            if !typed.contains(&e.name.as_str()) {
                typed.push(&e.name);
                out.push_str(&format!("# TYPE {} {}\n", e.name, e.metric.type_name()));
            }
            match &e.metric {
                Metric::Counter(c) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        e.name,
                        Self::fmt_labels(&e.labels, None),
                        c.get()
                    ));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        e.name,
                        Self::fmt_labels(&e.labels, None),
                        g.get()
                    ));
                }
                Metric::GaugeFn(f) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        e.name,
                        Self::fmt_labels(&e.labels, None),
                        f()
                    ));
                }
                Metric::Histogram(h) => {
                    let snap = h.snapshot();
                    let bounds = bucket_bounds();
                    let mut cum = 0u64;
                    for (i, c) in snap.counts.iter().enumerate() {
                        cum += c;
                        let le = if i < bounds.len() {
                            format!("{}", bounds[i])
                        } else {
                            "+Inf".to_string()
                        };
                        out.push_str(&format!(
                            "{}_bucket{} {}\n",
                            e.name,
                            Self::fmt_labels(&e.labels, Some(("le", &le))),
                            cum
                        ));
                    }
                    out.push_str(&format!(
                        "{}_sum{} {}\n",
                        e.name,
                        Self::fmt_labels(&e.labels, None),
                        snap.sum
                    ));
                    out.push_str(&format!(
                        "{}_count{} {}\n",
                        e.name,
                        Self::fmt_labels(&e.labels, None),
                        snap.count
                    ));
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// trace spans
// ---------------------------------------------------------------------------

/// Pipeline stage a span was recorded from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Admission,
    Queue,
    Prefill,
    Segment,
    PrefixFork,
    Decode,
    Sample,
}

impl Stage {
    pub fn as_str(&self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::Queue => "queue",
            Stage::Prefill => "prefill",
            Stage::Segment => "segment",
            Stage::PrefixFork => "prefix_fork",
            Stage::Decode => "decode",
            Stage::Sample => "sample",
        }
    }
}

/// One recorded stage: request id, session, stage, owning shard, and
/// start/duration in microseconds relative to the trace epoch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub req: u64,
    pub session: u64,
    pub stage: Stage,
    pub shard: u32,
    pub start_us: u64,
    pub dur_us: u64,
}

impl Span {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("req", Json::Str(format!("{:x}", self.req))),
            ("session", Json::Num(self.session as f64)),
            ("stage", Json::Str(self.stage.as_str().to_string())),
            ("shard", Json::Num(self.shard as f64)),
            ("start_us", Json::Num(self.start_us as f64)),
            ("dur_us", Json::Num(self.dur_us as f64)),
        ])
    }
}

/// Bounded span ring: at capacity the oldest span is dropped, so memory
/// stays fixed under sustained traffic.
pub struct TraceRing {
    cap: usize,
    buf: Mutex<VecDeque<Span>>,
}

impl TraceRing {
    pub fn new(cap: usize) -> TraceRing {
        TraceRing { cap: cap.max(1), buf: Mutex::new(VecDeque::new()) }
    }

    pub fn push(&self, s: Span) {
        let mut b = self.buf.lock().unwrap();
        if b.len() == self.cap {
            b.pop_front();
        }
        b.push_back(s);
    }

    pub fn len(&self) -> usize {
        self.buf.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn snapshot(&self) -> Vec<Span> {
        self.buf.lock().unwrap().iter().copied().collect()
    }
}

/// Per-shard trace rings sharing one epoch. Shard workers push into
/// their own ring (uncontended); `dump` merges and time-sorts for
/// `GET /v1/trace`.
pub struct Trace {
    rings: Vec<TraceRing>,
    t0: Instant,
}

/// Default per-shard span capacity (spans are 48 bytes, so the default
/// bound is ~25 KiB per shard).
pub const TRACE_RING_CAP: usize = 512;

impl Trace {
    pub fn new(shards: usize, cap_per_shard: usize) -> Trace {
        Trace {
            rings: (0..shards.max(1)).map(|_| TraceRing::new(cap_per_shard)).collect(),
            t0: Instant::now(),
        }
    }

    /// Microseconds since the trace epoch — span start timestamps.
    pub fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    /// Record a span into `shard`'s ring. No-op below `Trace` level.
    pub fn push(&self, shard: usize, span: Span) {
        if !trace_enabled() {
            return;
        }
        self.rings[shard % self.rings.len()].push(span);
    }

    /// Last `n` spans across all shards, ordered by start time.
    pub fn dump(&self, n: usize) -> Vec<Span> {
        let mut all: Vec<Span> = self.rings.iter().flat_map(|r| r.snapshot()).collect();
        all.sort_by_key(|s| (s.start_us, s.dur_us, s.shard));
        if all.len() > n {
            all.drain(..all.len() - n);
        }
        all
    }
}

// ---------------------------------------------------------------------------
// per-request timing summary
// ---------------------------------------------------------------------------

/// Wall-clock split of one completion, reported in the blocking response
/// and the SSE `done` record. All fields are microseconds; integer so
/// the carrying enums keep their derived `Eq`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Timing {
    /// Submission until the first shard dispatch picked the request up.
    pub queue_us: u64,
    /// Time spent in prompt prefill (incl. fan-out and prefix fork).
    pub prefill_us: u64,
    /// Time spent in decode + sampling quanta.
    pub decode_us: u64,
    /// Submission until the completion was sent.
    pub total_us: u64,
}

impl Timing {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queue_us", Json::Num(self.queue_us as f64)),
            ("prefill_us", Json::Num(self.prefill_us as f64)),
            ("decode_us", Json::Num(self.decode_us as f64)),
            ("total_us", Json::Num(self.total_us as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::stats;

    #[test]
    fn level_parses_and_round_trips() {
        for s in ["off", "metrics", "trace"] {
            assert_eq!(ObsLevel::parse(s).unwrap().as_str(), s);
        }
        assert!(ObsLevel::parse("verbose").is_err());
    }

    #[test]
    fn request_ids_are_unique_and_hashing_is_stable() {
        let a = next_request_id();
        let b = next_request_id();
        assert_ne!(a, b);
        assert_eq!(hash_request_id("abc"), hash_request_id("abc"));
        assert_ne!(hash_request_id("abc"), hash_request_id("abd"));
    }

    #[test]
    fn every_recorded_value_lands_in_its_containing_bucket() {
        // property: for any positive magnitude, the chosen bucket's
        // bounds actually contain the value
        Prop::new(0x0b5_0001).cases(500).check(|case| {
            let exp = case.rng.f64() * 50.0 - 4.0; // 2^-4 .. 2^46
            let v = 2f64.powf(exp);
            let i = bucket_index(v);
            let bounds = bucket_bounds();
            let lower = if i == 0 { 0.0 } else { bounds[i - 1] };
            if v <= lower {
                return Err(format!("v={v} at bucket {i} not above lower {lower}"));
            }
            if i < bounds.len() && v > bounds[i] {
                return Err(format!("v={v} at bucket {i} above upper {}", bounds[i]));
            }
            Ok(())
        });
    }

    #[test]
    fn merged_percentiles_stay_within_one_bucket_width_of_exact() {
        Prop::new(0x0b5_0002).cases(60).check(|case| {
            let n = 2 + case.rng.usize_below(400);
            let xs: Vec<f64> = (0..n)
                .map(|_| 2f64.powf(case.rng.f64() * 30.0))
                .collect();
            // split the sample across two histograms, then merge — the
            // merged snapshot must agree with the whole-sample exact
            // percentile to within the winning bucket's width
            let (ha, hb) = (Histogram::new(), Histogram::new());
            for (i, &x) in xs.iter().enumerate() {
                if i % 2 == 0 { ha.record(x) } else { hb.record(x) };
            }
            let mut snap = ha.snapshot();
            snap.merge(&hb.snapshot());
            let bounds = bucket_bounds();
            let mut sorted = xs.clone();
            sorted.sort_by(f64::total_cmp);
            for p in [0.0, 50.0, 90.0, 99.0, 100.0] {
                let approx = snap.percentile(p);
                // the sample at the histogram's rank convention — the
                // approx percentile must land inside (within) the bucket
                // containing it, i.e. within one bucket-width of the
                // exact sample percentile at that rank
                let rank = (p / 100.0) * (n - 1) as f64;
                let exact = sorted[rank.floor() as usize];
                let i = bucket_index(exact);
                let lower = if i == 0 { 0.0 } else { bounds[i - 1] };
                let upper = if i < bounds.len() { bounds[i] } else { f64::MAX };
                if approx < lower - 1e-9 || approx > upper + 1e-9 {
                    return Err(format!(
                        "p{p}: approx {approx} outside bucket [{lower}, {upper}] \
                         of exact rank sample {exact} (n={n})"
                    ));
                }
                // and the interpolated stats::percentile stays within the
                // bucket span bridging its two neighbouring samples
                let full = stats::percentile(&xs, p);
                let hi_s = sorted[rank.ceil() as usize];
                let hi_i = bucket_index(hi_s);
                let hi_up = if hi_i < bounds.len() { bounds[hi_i] } else { f64::MAX };
                if (approx - full).abs() > (hi_up - lower) + 1e-9 {
                    return Err(format!(
                        "p{p}: approx {approx} vs interpolated {full} beyond \
                         bridged bucket span (n={n})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn histogram_snapshot_counts_and_sum_are_exact() {
        let h = Histogram::new();
        for v in [1.0, 10.0, 100.0, 1000.0] {
            h.record(v);
        }
        h.record(f64::NAN); // clamps to 0, still counted
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 1111.0);
        assert_eq!(s.counts.iter().sum::<u64>(), 5);
    }

    #[test]
    fn trace_ring_wraps_keeping_the_newest_spans_in_order() {
        let ring = TraceRing::new(8);
        let span = |i: u64| Span {
            req: i,
            session: 7,
            stage: Stage::Decode,
            shard: 0,
            start_us: i,
            dur_us: 1,
        };
        for i in 0..20 {
            ring.push(span(i));
        }
        let got = ring.snapshot();
        assert_eq!(got.len(), 8);
        let want: Vec<u64> = (12..20).collect();
        assert_eq!(got.iter().map(|s| s.req).collect::<Vec<_>>(), want);
    }

    #[test]
    fn trace_dump_merges_shards_sorted_by_start() {
        let _guard = test_level_lock();
        let prev = level();
        set_level(ObsLevel::Trace);
        let tr = Trace::new(2, 16);
        for i in 0..10u64 {
            let span = Span {
                req: i,
                session: i,
                stage: Stage::Queue,
                shard: (i % 2) as u32,
                start_us: 100 - i, // pushed in reverse start order
                dur_us: 1,
            };
            tr.push((i % 2) as usize, span);
        }
        let got = tr.dump(6);
        set_level(prev);
        assert_eq!(got.len(), 6);
        for w in got.windows(2) {
            assert!(w[0].start_us <= w[1].start_us);
        }
    }

    #[test]
    fn concurrent_hammer_loses_no_updates() {
        let reg = Registry::new();
        let c = reg.counter("ovq_hammer_total", &[]);
        let h = reg.histogram("ovq_hammer_ns", &[]);
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let (c, h) = (c.clone(), h.clone());
                scope.spawn(move || {
                    for i in 0..10_000u64 {
                        c.inc();
                        h.record((t * 10_000 + i) as f64 % 997.0 + 1.0);
                    }
                });
            }
        });
        assert_eq!(c.get(), 40_000);
        let s = reg.histogram_snapshot("ovq_hammer_ns");
        assert_eq!(s.count, 40_000);
        assert_eq!(s.counts.iter().sum::<u64>(), 40_000);
    }

    #[test]
    fn registry_handles_are_idempotent_by_name_and_labels() {
        let reg = Registry::new();
        let a = reg.counter("ovq_x_total", &[("route", "a")]);
        let b = reg.counter("ovq_x_total", &[("route", "a")]);
        let other = reg.counter("ovq_x_total", &[("route", "b")]);
        a.inc();
        b.inc();
        other.inc();
        assert_eq!(a.get(), 2);
        assert_eq!(other.get(), 1);
    }

    #[test]
    fn prometheus_rendering_emits_typed_series() {
        let reg = Registry::new();
        reg.counter("ovq_req_total", &[("route", "completions")]).add(3);
        reg.gauge("ovq_inflight", &[]).set(2);
        reg.gauge_fn("ovq_view", &[], || 1.5);
        let h = reg.histogram("ovq_lat_ns", &[("stage", "decode")]);
        h.record(5.0);
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE ovq_req_total counter"));
        assert!(text.contains("ovq_req_total{route=\"completions\"} 3"));
        assert!(text.contains("ovq_inflight 2"));
        assert!(text.contains("ovq_view 1.5"));
        assert!(text.contains("# TYPE ovq_lat_ns histogram"));
        assert!(text.contains("le=\"+Inf\""));
        assert!(text.contains("ovq_lat_ns_sum{stage=\"decode\"} 5"));
        assert!(text.contains("ovq_lat_ns_count{stage=\"decode\"} 1"));
        // cumulative bucket counts end at the total
        let last_bucket = text
            .lines()
            .filter(|l| l.starts_with("ovq_lat_ns_bucket"))
            .last()
            .unwrap();
        assert!(last_bucket.ends_with(" 1"));
    }

    #[test]
    fn timing_serializes_every_field() {
        let t = Timing { queue_us: 1, prefill_us: 2, decode_us: 3, total_us: 6 };
        let j = t.to_json();
        for k in ["queue_us", "prefill_us", "decode_us", "total_us"] {
            assert!(j.get(k).is_some(), "missing {k}");
        }
    }
}
