//! Seeded property-testing harness (proptest is unavailable offline).
//!
//! `Prop::new(seed).cases(n).check(|rng| { ... })` runs the closure across
//! n pseudo-random cases; failures report the per-case sub-seed so a case
//! can be replayed exactly with `replay(subseed, f)`. Generators grow with
//! the case index, giving a cheap small-to-large search order (shrinking by
//! construction rather than post-hoc).

use super::rng::Rng;

pub struct Prop {
    pub seed: u64,
    pub n_cases: usize,
}

/// Per-case context: seeded RNG + a size hint that grows with case index.
pub struct Case {
    pub rng: Rng,
    pub size: usize,
    pub index: usize,
}

impl Case {
    /// Integer in [1, size] — the canonical "grows with case index" length.
    pub fn len(&mut self) -> usize {
        1 + self.rng.usize_below(self.size)
    }
}

impl Prop {
    pub fn new(seed: u64) -> Prop {
        Prop { seed, n_cases: 64 }
    }

    pub fn cases(mut self, n: usize) -> Prop {
        self.n_cases = n;
        self
    }

    /// Run the property; panics with the failing sub-seed on error.
    pub fn check<F: FnMut(&mut Case) -> Result<(), String>>(&self, mut f: F) {
        for i in 0..self.n_cases {
            let subseed = self
                .seed
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(i as u64);
            let mut case = Case {
                rng: Rng::new(subseed),
                size: 2 + i * 4, // grow: early cases are tiny
                index: i,
            };
            if let Err(msg) = f(&mut case) {
                panic!(
                    "property failed at case {i} (subseed {subseed:#x}, size {}): {msg}",
                    case.size
                );
            }
        }
    }
}

/// Replay one failing case by sub-seed.
pub fn replay<F: FnMut(&mut Case) -> Result<(), String>>(subseed: u64, size: usize, mut f: F) {
    let mut case = Case { rng: Rng::new(subseed), size, index: 0 };
    if let Err(msg) = f(&mut case) {
        panic!("replayed case failed: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Prop::new(1).cases(32).check(|c| {
            let n = c.len();
            let v: Vec<u64> = (0..n as u64).collect();
            if v.len() == n {
                Ok(())
            } else {
                Err("len mismatch".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        Prop::new(2).cases(50).check(|c| {
            if c.size < 20 {
                Ok(())
            } else {
                Err("size grew past 20".into())
            }
        });
    }
}
