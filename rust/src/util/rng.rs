//! Deterministic PRNG (SplitMix64 seeding + Xoshiro256**), plus the
//! distributions the task generators need. rand/rand_distr are unavailable
//! offline; this is the standard public-domain construction.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-thread / per-task generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// The raw generator state — with [`Rng::from_state`], the snapshot
    /// hook that lets a frozen generation session resume its sampling
    /// stream bit-identically (the sampler's replayability contract).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator mid-stream from [`Rng::state`] words.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi) (integers).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Draw an index from an unnormalized categorical distribution over
    /// `probs` (non-positive entries are never chosen). One uniform draw,
    /// then a cumulative walk in f64 — the accumulation order is the
    /// slice order, so the draw sequence for a fixed seed is a pure
    /// function of the inputs and replays identically across platforms
    /// (the sampler's determinism contract). Returns the last positive
    /// index if rounding spills past the total; 0 if no entry is positive.
    pub fn categorical(&mut self, probs: &[f32]) -> usize {
        let mut total = 0.0f64;
        for &p in probs {
            if p > 0.0 {
                total += p as f64;
            }
        }
        if total <= 0.0 {
            return 0;
        }
        let mut u = self.f64() * total;
        let mut last = 0usize;
        for (i, &p) in probs.iter().enumerate() {
            if p > 0.0 {
                last = i;
                let p = p as f64;
                if u < p {
                    return i;
                }
                u -= p;
            }
        }
        last
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n), k <= n (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Zipfian sample over [0, n) with exponent s (rejection-inversion-lite:
    /// CDF table would be heavy for large n; this uses the approximate
    /// inverse power method, adequate for synthetic corpora).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // inverse-CDF on the continuous approximation
        let u = self.f64().max(1e-12);
        if (s - 1.0).abs() < 1e-9 {
            let h = (n as f64).ln();
            return ((u * h).exp() - 1.0).floor().min((n - 1) as f64) as usize;
        }
        let e = 1.0 - s;
        let h = ((n as f64).powf(e) - 1.0) / e;
        let x = (1.0 + u * h * e).powf(1.0 / e) - 1.0;
        (x.floor() as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(2);
        let m: f64 = (0..10000).map(|_| r.f64()).sum::<f64>() / 10000.0;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        let idx = r.sample_indices(50, 20);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(6);
        let mut lo = 0usize;
        for _ in 0..2000 {
            if r.zipf(1000, 1.1) < 10 {
                lo += 1;
            }
        }
        // top-10 of 1000 should take a large share under zipf(1.1)
        assert!(lo > 400, "low-rank share {lo}");
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(8);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn categorical_golden_sequence_is_seed_deterministic() {
        // the sampler replayability contract: a fixed seed produces one
        // fixed index sequence, reproducible draw-for-draw by a second
        // generator with the same seed, and reconstructible from the raw
        // uniform stream (the draw is a pure cumulative walk)
        let probs = [0.1f32, 0.0, 0.4, 0.25, 0.25];
        let mut a = Rng::new(0xCA7);
        let mut b = Rng::new(0xCA7);
        let mut mirror = Rng::new(0xCA7);
        let mut seq = Vec::new();
        for _ in 0..64 {
            let i = a.categorical(&probs);
            seq.push(i);
            assert_eq!(i, b.categorical(&probs), "same seed must replay the same draw");
            // reconstruct from the raw uniform: same walk, by hand
            let total: f64 = probs.iter().filter(|&&p| p > 0.0).map(|&p| p as f64).sum();
            let mut u = mirror.f64() * total;
            let mut want = 0usize;
            for (j, &p) in probs.iter().enumerate() {
                if p > 0.0 {
                    want = j;
                    if u < p as f64 {
                        break;
                    }
                    u -= p as f64;
                }
            }
            assert_eq!(i, want, "draw must be the cumulative walk of the uniform");
        }
        // every positive-mass index appears over 64 draws; index 1 never
        let mut seen = [false; 5];
        seq.iter().for_each(|&i| seen[i] = true);
        assert!(seen[0] && seen[2] && seen[3] && seen[4], "support not covered: {seq:?}");
        assert!(!seen[1], "zero-mass index drawn");
        // a different seed diverges somewhere in 64 draws
        let mut c = Rng::new(0xCA8);
        let other: Vec<usize> = (0..64).map(|_| c.categorical(&probs)).collect();
        assert_ne!(seq, other, "seed must matter");
    }

    #[test]
    fn categorical_edge_cases() {
        let mut r = Rng::new(3);
        assert_eq!(r.categorical(&[]), 0, "empty support");
        assert_eq!(r.categorical(&[0.0, -1.0, f32::NEG_INFINITY]), 0, "no positive mass");
        assert_eq!(r.categorical(&[0.0, 0.0, 7.0]), 2, "single-mass index always wins");
        // a one-hot at index 0 likewise
        for _ in 0..8 {
            assert_eq!(r.categorical(&[1.0, 0.0]), 0);
        }
    }

    #[test]
    fn state_round_trip_resumes_the_stream() {
        let mut a = Rng::new(77);
        for _ in 0..10 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64(), "restored stream must continue in place");
        }
    }
}
