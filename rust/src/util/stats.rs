//! Small statistics helpers used by metrics, benches and experiments.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Percentile via linear interpolation on the sorted copy; p in [0, 100].
/// Sorted with `total_cmp`, so a stray NaN latency sorts last instead of
/// panicking the reporting path.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Simple exponential moving average accumulator.
#[derive(Debug, Clone)]
pub struct Ema {
    pub alpha: f64,
    pub value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        };
        self.value = Some(v);
        v
    }
}

/// Bin a (position, value) stream into fixed-width position bins and
/// report per-bin means — used for loss-vs-token-position curves (Fig. 6).
pub fn binned_means(
    pairs: &[(usize, f64)],
    bin: usize,
    max_pos: usize,
) -> Vec<(usize, f64, usize)> {
    let nbins = max_pos.div_ceil(bin);
    let mut sum = vec![0.0; nbins];
    let mut cnt = vec![0usize; nbins];
    for &(p, v) in pairs {
        if p < max_pos {
            sum[p / bin] += v;
            cnt[p / bin] += 1;
        }
    }
    (0..nbins)
        .filter(|&i| cnt[i] > 0)
        .map(|i| (i * bin + bin / 2, sum[i] / cnt[i] as f64, cnt[i]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.2909944487).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_survives_nan_inputs() {
        // regression: partial_cmp().unwrap() used to panic here; with
        // total_cmp the NaN sorts last and the low percentiles stay sane
        let xs = [4.0, f64::NAN, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        e.update(0.0);
        for _ in 0..30 {
            e.update(10.0);
        }
        assert!((e.value.unwrap() - 10.0).abs() < 1e-3);
    }

    #[test]
    fn binning() {
        let pairs = [(0, 1.0), (1, 3.0), (10, 5.0)];
        let bins = binned_means(&pairs, 8, 16);
        assert_eq!(bins.len(), 2);
        assert!((bins[0].1 - 2.0).abs() < 1e-12);
        assert!((bins[1].1 - 5.0).abs() < 1e-12);
    }
}
