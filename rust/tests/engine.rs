//! Integration tests for the sharded decode engine: the multi-thread
//! bit-identity golden cross-check, scheduler fairness under equal
//! offered load, bounded-queue backpressure, and the eviction/restore
//! accounting contract. All offline (tier-1) — no artifacts or PJRT.

use std::collections::HashMap;

use ovq::analysis::memory;
use ovq::coordinator::engine::{session_seed, DecodeEngine, EngineConfig, EngineOut};
use ovq::coordinator::sampler::{SamplingParams, StopCriteria};
use ovq::coordinator::traffic::{self, TrafficConfig};
use ovq::ovqcore::bank::{DecodeChunk, MixerBank, ShardBank};
use ovq::ovqcore::lm::LmConfig;
use ovq::ovqcore::memstate::{MixerGeom, MixerKind};
use ovq::ovqcore::mixer::{PrefillMode, Scratch, SeqMixer};
use ovq::ovqcore::stack::{LayerStack, StackConfig};
use ovq::ovqcore::{gdn::GdnState, snapshot};
use ovq::util::rng::Rng;

/// Run a trace through an engine with `threads` workers and return every
/// output keyed by (session, seq).
fn run_trace(
    threads: usize,
    max_resident: usize,
    events: &[ovq::coordinator::traffic::TrafficEvent],
) -> HashMap<(u64, usize), Vec<f32>> {
    let mut cfg = EngineConfig::new(MixerKind::Ovq { n_max: 32 }, 2, 8, 16);
    cfg.threads = threads;
    cfg.max_resident = max_resident;
    cfg.queue_depth = 8;
    cfg.collect_outputs = true;
    let engine = DecodeEngine::start(cfg);
    let mut sink = Vec::new();
    traffic::replay(&engine, events, 0xDA7A, Some(&mut sink));
    engine.flush_all();
    let report = engine.finish();
    sink.extend(report.outputs);
    sink.into_iter().map(|EngineOut { session, seq, out }| ((session, seq), out)).collect()
}

#[test]
fn multi_thread_output_bit_identical_to_single_thread() {
    // the tentpole's golden cross-check: the same zipf trace through 1, 2
    // and 4 worker threads — with a residency cap tight enough to force
    // evict/restore churn — must produce bit-identical outputs per stream
    let mut tcfg = TrafficConfig::new(12, 120);
    tcfg.chunk_sizes = vec![1, 4, 16];
    let events = traffic::generate(&tcfg);
    let single = run_trace(1, 3, &events);
    assert!(!single.is_empty());
    for threads in [2usize, 4] {
        let multi = run_trace(threads, 3, &events);
        assert_eq!(single.len(), multi.len(), "{threads} threads lost outputs");
        for (key, out) in &single {
            let got = multi
                .get(key)
                .unwrap_or_else(|| panic!("{threads} threads missing chunk {key:?}"));
            assert!(
                out.iter().zip(got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "outputs for session {} chunk {} differ at {} threads",
                key.0,
                key.1,
                threads
            );
        }
    }
}

#[test]
fn eviction_churn_matches_uncapped_run() {
    // snapshot/restore must be invisible to the streams: a run whose
    // sessions constantly bounce through eviction (cap 1) must equal the
    // run where every session stays resident
    let mut tcfg = TrafficConfig::new(6, 60);
    tcfg.seed = 0x5E55;
    let events = traffic::generate(&tcfg);
    let roomy = run_trace(1, 64, &events);
    let cramped = run_trace(1, 1, &events);
    assert_eq!(roomy.len(), cramped.len());
    for (key, out) in &roomy {
        assert_eq!(out, &cramped[key], "eviction changed session {} chunk {}", key.0, key.1);
    }
}

#[test]
fn engine_reports_eviction_accounting() {
    // cap 1 on a single shard: with two interleaved sessions every
    // arrival swaps residency, so at shutdown one session is resident and
    // one is a snapshot blob — and the accounting must say exactly that
    let mut cfg = EngineConfig::new(MixerKind::Ovq { n_max: 32 }, 2, 8, 16);
    cfg.threads = 1;
    cfg.max_resident = 1;
    let engine = DecodeEngine::start(cfg);
    let hd = engine.heads() * engine.d_head();
    for round in 0..4usize {
        for session in [0u64, 1] {
            engine.submit(session, traffic::synth_chunk(1, session, round, 8, hd));
        }
    }
    let report = engine.finish();
    let shard = &report.shards[0];
    assert!(shard.evictions >= 7, "expected swap churn, got {}", shard.evictions);
    assert!(shard.restores >= 6, "expected restores, got {}", shard.restores);
    assert_eq!(shard.sessions, 2);
    assert!(shard.resident_bytes > 0, "one session stays live");
    assert!(shard.snapshot_bytes > 0, "one session is frozen to a blob");
    // the frozen session's accounted bytes are exactly the blob: rebuild
    // the blob size bound from a same-shape mixer snapshot
    let probe: Box<dyn SeqMixer> = MixerKind::Ovq { n_max: 32 }.build(8, 16, 1);
    let empty_blob = snapshot::save(probe.as_ref());
    assert!(
        shard.snapshot_bytes >= empty_blob.len(),
        "blob accounting below the framing floor"
    );
}

#[test]
fn explicit_evict_is_invisible_to_the_stream() {
    // the engine-level abandon API: chunks, evict, more chunks — the
    // eviction must be counted, must freeze real bytes, and must not
    // change a single output bit vs the run that never evicted
    let mk_cfg = || {
        let mut cfg = EngineConfig::new(MixerKind::Ovq { n_max: 32 }, 2, 8, 16);
        cfg.threads = 1;
        cfg.collect_outputs = true;
        cfg
    };
    let run = |evict: bool| {
        let engine = DecodeEngine::start(mk_cfg());
        let hd = engine.heads() * engine.d_head();
        for round in 0..2usize {
            engine.submit(5, traffic::synth_chunk(7, 5, round, 10, hd));
        }
        if evict {
            engine.evict(5);
        }
        for round in 2..4usize {
            engine.submit(5, traffic::synth_chunk(7, 5, round, 10, hd));
        }
        engine.finish()
    };
    let plain = run(false);
    let evicted = run(true);
    assert_eq!(evicted.shards[0].evictions, 1);
    assert_eq!(evicted.shards[0].restores, 1);
    assert_eq!(plain.shards[0].evictions, 0);
    assert_eq!(evicted.outputs.len(), 4);
    for (a, b) in plain.outputs.iter().zip(&evicted.outputs) {
        assert_eq!((a.session, a.seq), (b.session, b.seq));
        assert!(
            a.out.iter().zip(&b.out).all(|(x, y)| x.to_bits() == y.to_bits()),
            "evict/restore changed chunk {} of the stream",
            a.seq
        );
    }
}

#[test]
fn equal_offered_load_is_served_fairly_mid_run() {
    // satellite: with equal offered load, no stream's completed-token
    // count may lag the median by more than one chunk — checked mid-drain
    // on the round-robin bank at several points
    let (streams, d, chunk_len) = (5usize, 8usize, 16usize);
    let mut rng = Rng::new(21);
    let mut bank = MixerBank::new(streams, 1, |_, _| {
        MixerKind::Ovq { n_max: 32 }.build(d, 16, 9)
    });
    let mut mk = |rng: &mut Rng| DecodeChunk {
        queries: (0..chunk_len * d).map(|_| rng.normal() as f32).collect(),
        keys: (0..chunk_len * d).map(|_| rng.normal() as f32).collect(),
        values: (0..chunk_len * d).map(|_| rng.normal() as f32).collect(),
    };
    for _ in 0..4 {
        for s in 0..streams {
            let c = mk(&mut rng);
            bank.submit(s, c);
        }
    }
    let total = 4 * streams;
    for step in 0..total {
        bank.step().expect("queued work remains");
        let mut tokens: Vec<usize> = bank.stats.iter().map(|st| st.tokens).collect();
        tokens.sort_unstable();
        let median = tokens[tokens.len() / 2];
        for (s, st) in bank.stats.iter().enumerate() {
            assert!(
                st.tokens + chunk_len >= median,
                "step {step}: stream {s} at {} tokens lags median {median} by more \
                 than one chunk",
                st.tokens
            );
        }
    }
}

#[test]
fn engine_equal_load_completes_equally() {
    // end-state fairness through the threaded engine: equal offered load,
    // equal completions — no session starves on any shard
    let mut cfg = EngineConfig::new(MixerKind::Gdn, 2, 8, 16);
    cfg.threads = 4;
    let engine = DecodeEngine::start(cfg);
    let hd = engine.heads() * engine.d_head();
    for round in 0..5usize {
        for session in 0..9u64 {
            engine.submit(session, traffic::synth_chunk(2, session, round, 8, hd));
        }
    }
    let report = engine.finish();
    assert_eq!(report.sessions.len(), 9);
    for (id, st) in &report.sessions {
        assert_eq!(st.tokens, 5 * 8, "session {id} under-served");
        assert_eq!(st.chunks, 5);
    }
}

// ---------------------------------------------------------------- prefill

/// Decode-path ingestion of the same tokens a prefill would absorb:
/// submit the prompt as `piece`-token decode chunks. Outputs concatenate
/// to what one submit_prefill call produces (bit-identically) — the
/// engine-level prefill golden reference.
fn submit_as_decode_chunks(
    engine: &DecodeEngine,
    session: u64,
    prompt: &DecodeChunk,
    piece: usize,
    hd: usize,
) {
    let total = prompt.keys.len() / hd;
    let mut i = 0;
    while i < total {
        let len = piece.min(total - i);
        let (a, b) = (i * hd, (i + len) * hd);
        engine.submit(
            session,
            DecodeChunk {
                queries: prompt.queries[a..b].to_vec(),
                keys: prompt.keys[a..b].to_vec(),
                values: prompt.values[a..b].to_vec(),
            },
        );
        i += len;
    }
}

#[test]
fn long_prefill_interleaves_with_decode_and_stays_bit_identical() {
    // the tentpole scheduling claim, on one shard: a 64k prompt for
    // session A churns through quantized prefill while session B keeps
    // decoding — B's chunks must complete BEFORE the prompt does
    // (bounded lag, not head-of-line blocking), B's outputs must be
    // bit-identical to a prompt-free run, and A's prompt output must be
    // bit-identical to ingesting the same tokens as decode chunks.
    let (heads, d_head) = (1usize, 4usize);
    let hd = heads * d_head;
    let prompt_len = 65_536usize;
    let (sess_a, sess_b) = (11u64, 7u64);
    let prompt = traffic::synth_chunk(0xBEEF, sess_a, 0, prompt_len, hd);
    let mk_cfg = || {
        let mut cfg = EngineConfig::new(MixerKind::Ovq { n_max: 16 }, heads, d_head, 8);
        cfg.threads = 1; // both sessions land on the one shard
        cfg.queue_depth = 64;
        cfg.prefill_quantum = 256;
        cfg.collect_outputs = true;
        cfg
    };
    let decode_chunks = 24usize;

    // run 1: prompt + concurrent decode traffic
    let engine = DecodeEngine::start(mk_cfg());
    for seq in 0..8usize {
        engine.submit(sess_b, traffic::synth_chunk(0xD0, sess_b, seq, 8, hd));
    }
    engine.submit_prefill(sess_a, prompt.clone());
    for seq in 8..decode_chunks {
        engine.submit(sess_b, traffic::synth_chunk(0xD0, sess_b, seq, 8, hd));
    }
    let mixed = engine.finish();

    // B completed in full and the prompt was ingested whole
    let shard = &mixed.shards[0];
    assert_eq!(shard.prefill_chunks, 1);
    assert_eq!(shard.prefill_tokens, prompt_len);
    assert_eq!(shard.chunks, decode_chunks);
    assert!(shard.prefill_busy > std::time::Duration::ZERO);
    assert!(shard.busy > shard.prefill_busy, "decode occupancy must be visible");
    assert_eq!(shard.ttft_ns.len(), 1);

    // continuous batching: with 256-token quanta the prompt takes 256
    // scheduling rounds, so every decode chunk (24 of them) completes
    // before the prompt — single worker + single out channel preserve
    // completion order
    let a_pos = mixed
        .outputs
        .iter()
        .position(|o| o.session == sess_a)
        .expect("prompt output collected");
    let decode_before: usize =
        mixed.outputs[..a_pos].iter().filter(|o| o.session == sess_b).count();
    assert!(
        decode_before >= decode_chunks / 2,
        "only {decode_before}/{decode_chunks} decode chunks overtook the 64k prefill"
    );
    // bounded lag: any decode chunk submitted after the prompt still
    // finished before it, so no decode wait can reach the prompt's ttft
    let ttft = shard.ttft_ns[0];
    let worst_decode = shard.latency_ns.iter().cloned().fold(0.0f64, f64::max);
    assert!(
        worst_decode < ttft,
        "decode p100 {worst_decode}ns not bounded by prompt ttft {ttft}ns"
    );

    // run 2: same decode traffic, no prompt — B must not feel A at all
    let engine = DecodeEngine::start(mk_cfg());
    for seq in 0..decode_chunks {
        engine.submit(sess_b, traffic::synth_chunk(0xD0, sess_b, seq, 8, hd));
    }
    let plain = engine.finish();
    let b_mixed: Vec<&EngineOut> =
        mixed.outputs.iter().filter(|o| o.session == sess_b).collect();
    let b_plain: Vec<&EngineOut> =
        plain.outputs.iter().filter(|o| o.session == sess_b).collect();
    assert_eq!(b_mixed.len(), b_plain.len());
    for (x, y) in b_mixed.iter().zip(&b_plain) {
        assert_eq!(x.seq, y.seq);
        assert!(
            x.out.iter().zip(&y.out).all(|(a, b)| a.to_bits() == b.to_bits()),
            "a concurrent prefill changed decode chunk {} of session B",
            x.seq
        );
    }

    // run 3: the prompt ingested through the DECODE path in 512-token
    // pieces — the engine-level golden: outputs concatenate bit-exactly
    // to the prefill path's single output
    let engine = DecodeEngine::start(mk_cfg());
    submit_as_decode_chunks(&engine, sess_a, &prompt, 512, hd);
    let golden = engine.finish();
    let mut golden_cat: Vec<f32> = Vec::with_capacity(prompt_len * hd);
    let mut a_outs: Vec<&EngineOut> =
        golden.outputs.iter().filter(|o| o.session == sess_a).collect();
    a_outs.sort_by_key(|o| o.seq);
    for o in a_outs {
        golden_cat.extend_from_slice(&o.out);
    }
    let a_prefill = &mixed.outputs[a_pos];
    assert_eq!(a_prefill.out.len(), golden_cat.len());
    assert!(
        a_prefill.out.iter().zip(&golden_cat).all(|(a, b)| a.to_bits() == b.to_bits()),
        "prefill path diverged from decode-path ingestion of the same prompt"
    );
}

#[test]
fn same_session_traffic_after_prefill_is_deferred_in_order() {
    // per-session ordering across the prefill boundary: decode chunks
    // submitted for a session AFTER its prompt must wait for the prompt
    // and produce exactly what a fully serial (decode-path) run produces
    let (heads, d_head) = (2usize, 8usize);
    let hd = heads * d_head;
    let sess = 5u64;
    let prompt = traffic::synth_chunk(0xAB, sess, 1_000_000, 1024, hd);
    let mk_cfg = || {
        let mut cfg = EngineConfig::new(MixerKind::Ovq { n_max: 32 }, heads, d_head, 16);
        cfg.threads = 1;
        cfg.prefill_quantum = 64;
        cfg.collect_outputs = true;
        cfg
    };

    let engine = DecodeEngine::start(mk_cfg());
    engine.submit(sess, traffic::synth_chunk(0xAB, sess, 0, 16, hd));
    engine.submit_prefill(sess, prompt.clone());
    engine.submit(sess, traffic::synth_chunk(0xAB, sess, 1, 16, hd));
    engine.flush_all();
    let with_prefill = engine.finish();

    let engine = DecodeEngine::start(mk_cfg());
    engine.submit(sess, traffic::synth_chunk(0xAB, sess, 0, 16, hd));
    submit_as_decode_chunks(&engine, sess, &prompt, 256, hd);
    engine.submit(sess, traffic::synth_chunk(0xAB, sess, 1, 16, hd));
    engine.flush_all();
    let serial = engine.finish();

    // stitch both runs into flat per-session streams and compare bits
    let flat = |outs: &[EngineOut]| -> Vec<f32> {
        let mut v: Vec<&EngineOut> = outs.iter().collect();
        v.sort_by_key(|o| o.seq);
        v.iter().flat_map(|o| o.out.iter().copied()).collect()
    };
    let a = flat(&with_prefill.outputs);
    let b = flat(&serial.outputs);
    assert_eq!(a.len(), b.len(), "streams must cover the same tokens");
    assert!(
        a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
        "prefill deferral reordered or altered the session's stream"
    );
    // and the trailing decode chunk really was sequenced after the prompt
    let seqs: Vec<usize> = {
        let mut s: Vec<usize> = with_prefill.outputs.iter().map(|o| o.seq).collect();
        s.sort_unstable();
        s
    };
    assert_eq!(seqs, vec![1, 2, 3]);
}

// --------------------------------------------------------------- fan-out

/// Long-prompt run exercising intra-request fan-out: one 600-token
/// prompt session (10 quanta at quantum 64 — well past the 2-quantum
/// eligibility floor) plus a decode neighbour and a post-prompt decode
/// chunk on the prompt session itself. Outputs keyed by (session, seq).
fn run_fanout(
    kind: MixerKind,
    mode: PrefillMode,
    threads: usize,
    fanout: bool,
    evict_mid: bool,
) -> HashMap<(u64, usize), Vec<f32>> {
    let (heads, d_head) = (2usize, 8usize);
    let hd = heads * d_head;
    let mut cfg = EngineConfig::new(kind, heads, d_head, 16);
    cfg.threads = threads;
    cfg.queue_depth = 64;
    cfg.prefill_quantum = 64;
    cfg.prefill_mode = mode;
    cfg.prefill_fanout = fanout;
    cfg.collect_outputs = true;
    let engine = DecodeEngine::start(cfg);
    engine.submit_prefill(1, traffic::synth_chunk(0xFA0, 1, 0, 600, hd));
    if evict_mid {
        // freeze the prompt session between fan-out rounds: the owner
        // must thaw the blob transparently and keep segmenting
        engine.evict(1);
    }
    for seq in 0..4usize {
        engine.submit(2, traffic::synth_chunk(0xD0, 2, seq, 8, hd));
    }
    // a decode chunk for the PROMPT session, submitted mid-fan-out: must
    // defer behind the whole prompt and land on the fanned-out state
    engine.submit(1, traffic::synth_chunk(0xD1, 1, 77, 8, hd));
    engine.flush_all();
    let report = engine.finish();
    report.outputs.into_iter().map(|o| ((o.session, o.seq), o.out)).collect()
}

fn assert_same_outputs(
    a: &HashMap<(u64, usize), Vec<f32>>,
    b: &HashMap<(u64, usize), Vec<f32>>,
    what: &str,
) {
    assert_eq!(a.len(), b.len(), "{what}: output count differs");
    for (key, out) in a {
        let got = b.get(key).unwrap_or_else(|| panic!("{what}: missing chunk {key:?}"));
        assert_eq!(out.len(), got.len(), "{what}: chunk {key:?} length differs");
        assert!(
            out.iter().zip(got).all(|(x, y)| x.to_bits() == y.to_bits()),
            "{what}: session {} chunk {} differs",
            key.0,
            key.1
        );
    }
}

#[test]
fn fanned_out_prefill_bit_identical_across_threads_for_exact_mixers() {
    // the fan-out golden for the exact-prefill mixers (OVQ / VQ / KV):
    // segments always cut at prefill-quantum boundaries and segment
    // outputs are computed from per-round state snapshots, so a 4-thread
    // fanned-out run must reproduce the 1-thread serial run bit for bit
    let kinds = [MixerKind::Ovq { n_max: 32 }, MixerKind::Vq { n: 32 }, MixerKind::FullAttention];
    for kind in kinds {
        let single = run_fanout(kind, PrefillMode::Exact, 1, false, false);
        assert!(single.len() >= 6, "{kind:?}: prompt + decode outputs expected");
        let fanned = run_fanout(kind, PrefillMode::Exact, 4, true, false);
        assert_same_outputs(&single, &fanned, &format!("{kind:?} fan-out"));
    }
}

#[test]
fn chunkwise_prefill_reproducible_across_threads_for_scan_mixers() {
    // tolerance mode on the scan mixers: chunkwise blocking restarts at
    // every prefill quantum on BOTH the serial and the fanned-out path,
    // so even the approximate mode is bit-reproducible across thread
    // counts for a fixed --prefill-chunk
    let mode = PrefillMode::Chunkwise { chunk: 24 };
    for kind in [MixerKind::Gdn, MixerKind::LinearAttention] {
        let single = run_fanout(kind, mode, 1, false, false);
        let fanned = run_fanout(kind, mode, 4, true, false);
        assert_same_outputs(&single, &fanned, &format!("{kind:?} chunkwise fan-out"));
    }
}

#[test]
fn evict_mid_fanout_prefill_is_invisible_to_the_stream() {
    // snapshot/evict while a prompt is mid-fan-out: the owner shard
    // thaws the blob on the next round and every output — the prompt's,
    // the neighbour's, and the deferred same-session decode chunk's —
    // stays bit-identical to the run that never froze
    let plain = run_fanout(MixerKind::Ovq { n_max: 32 }, PrefillMode::Exact, 4, true, false);
    let frozen = run_fanout(MixerKind::Ovq { n_max: 32 }, PrefillMode::Exact, 4, true, true);
    assert_same_outputs(&plain, &frozen, "mid-fan-out evict");
}

// ---------------------------------------------------------------- stacks

/// The 4-layer hybrid schedule the acceptance run serves: alternating
/// OVQ and windowed exact attention, tiny dims so the 64k prompt stays
/// tier-1-fast.
fn hybrid_stack() -> StackConfig {
    StackConfig::hybrid(
        4,
        8,
        1,
        4,
        16,
        vec![
            MixerKind::Ovq { n_max: 16 },
            MixerKind::SlidingWindow { window: 128 },
            MixerKind::Ovq { n_max: 16 },
            MixerKind::SlidingWindow { window: 128 },
        ],
    )
}

#[test]
fn stack_session_evicted_mid_prompt_at_depth_resumes_bit_identically() {
    // the satellite contract: a 3-layer stack session frozen between
    // prefill quanta — pending tails buffered at every layer depth —
    // must resume and finish the prompt bit-identically
    let cfg = StackConfig::hybrid(
        8,
        16,
        2,
        4,
        8,
        vec![
            MixerKind::Ovq { n_max: 16 },
            MixerKind::SlidingWindow { window: 20 },
            MixerKind::Ovq { n_max: 16 },
        ],
    );
    let d = cfg.d_model;
    let (total, cut) = (61usize, 27usize); // both mid-chunk (chunk = 8)
    let mk_shard = |cfg: StackConfig| {
        ShardBank::new(1, 4, move |id, _| {
            Box::new(LayerStack::new(cfg.clone(), id)) as Box<dyn SeqMixer>
        })
    };
    let mut shard = mk_shard(cfg.clone());
    let mut mirror = mk_shard(cfg);
    let mut rng = Rng::new(0x51AC);
    let x: Vec<f32> = (0..total * d).map(|_| rng.normal() as f32).collect();

    let mut got = shard
        .process_prefill(4, &x[..cut * d], &x[..cut * d], &x[..cut * d])
        .unwrap();
    shard.evict(4); // freeze the whole stack mid-prompt
    assert_eq!(shard.evictions, 1);
    got.extend_from_slice(
        &shard.process_prefill(4, &x[cut * d..], &x[cut * d..], &x[cut * d..]).unwrap(),
    );
    assert_eq!(shard.restores, 1, "re-arrival must thaw the stack blob");

    let want = mirror.process_prefill(4, &x, &x, &x).unwrap();
    assert_eq!(got.len(), want.len());
    assert!(
        got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
        "mid-prompt eviction changed a deep stack's prefill outputs"
    );
}

#[test]
fn hybrid_stack_64k_prefill_with_churn_is_thread_invariant_and_accounted() {
    // the acceptance run: a hybrid 4-layer stack serves a 64k-prompt
    // prefill plus concurrent decodes through the engine under LRU
    // eviction churn; outputs are bit-identical across 1 vs 4 shard
    // threads, and the live stack's state_bytes matches the
    // analysis/memory.rs analytic count exactly
    let stack = hybrid_stack();
    let d_model = stack.d_model;
    let prompt_len = 65_536usize;
    let prompt_sess = 11u64;
    let decode_sessions = [3u64, 5, 9];
    let prompt = traffic::synth_chunk(0x64AC, prompt_sess, 0, prompt_len, d_model);

    let run = |threads: usize| {
        let mut cfg = EngineConfig::for_stack(hybrid_stack());
        cfg.threads = threads;
        cfg.max_resident = 1; // every session swap churns through snapshots
        cfg.queue_depth = 16;
        cfg.prefill_quantum = 1024;
        cfg.collect_outputs = true;
        let engine = DecodeEngine::start(cfg);
        for seq in 0..3usize {
            for &s in &decode_sessions {
                engine.submit(s, traffic::synth_chunk(0xDEC, s, seq, 8, d_model));
            }
        }
        engine.submit_prefill(prompt_sess, prompt.clone());
        for seq in 3..6usize {
            for &s in &decode_sessions {
                engine.submit(s, traffic::synth_chunk(0xDEC, s, seq, 8, d_model));
            }
        }
        engine.flush_all();
        let report = engine.finish();
        let outs: HashMap<(u64, usize), Vec<f32>> = report
            .outputs
            .iter()
            .map(|o| ((o.session, o.seq), o.out.clone()))
            .collect();
        (report, outs)
    };

    let (r1, single) = run(1);
    assert_eq!(r1.prefill_tokens(), prompt_len);
    assert_eq!(r1.tokens, prompt_len + 3 * 6 * 8);
    assert!(r1.evictions() > 0, "cap 1 with 4 sessions must churn");
    assert!(r1.restores() > 0);
    assert_eq!(single.len(), 1 + 3 * 6, "one prompt output + every decode chunk");

    let (r4, multi) = run(4);
    assert_eq!(r4.prefill_tokens(), prompt_len);
    assert_eq!(single.len(), multi.len(), "4 threads lost outputs");
    for (key, out) in &single {
        let got = multi.get(key).unwrap_or_else(|| panic!("4 threads missing {key:?}"));
        assert_eq!(out.len(), got.len());
        assert!(
            out.iter().zip(got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "session {} chunk {} differs between 1 and 4 threads",
            key.0,
            key.1
        );
    }

    // per-layer telemetry: 4 rows following the hybrid schedule
    let layers = r1.layer_split();
    assert_eq!(layers.len(), 4);
    assert_eq!(layers[0].kind, "ovq");
    assert_eq!(layers[1].kind, "sliding_window");

    // state accounting: the engine's prompt session is seeded
    // deterministically, so a mirror stack fed the same prompt holds the
    // same state — and it must equal the analytic whole-stack count
    // EXACTLY (every layer at t = 64k: saturated OVQ dictionaries and
    // full windows)
    let seed = EngineConfig::for_stack(hybrid_stack()).seed;
    let mut mirror = LayerStack::new(hybrid_stack(), session_seed(seed, prompt_sess, 0));
    let mut out = vec![0.0f32; prompt_len * d_model];
    let mut scratch = Scratch::new();
    mirror.process_prefill(&prompt.queries, &prompt.keys, &prompt.values, &mut out, &mut scratch);
    assert!(
        out.iter().zip(&single[&(prompt_sess, 1)]).all(|(a, b)| a.to_bits() == b.to_bits()),
        "mirror stack diverged from the engine's prefill output"
    );
    mirror.flush();
    let g = MixerGeom { heads: 1, d_head: 4 };
    let analytic = memory::stack_state_bytes(&hybrid_stack().kinds, g, prompt_len);
    assert_eq!(
        mirror.state_bytes(),
        analytic,
        "live stack state must match the analytic accounting exactly"
    );
    assert!(analytic > 0);
}

// ------------------------------------------------------------- generation

/// The LM every generation test serves: a 2-layer hybrid (OVQ + windowed
/// exact attention) over a small vocabulary, with dims tiny enough that
/// self-feeding loops stay tier-1-fast.
fn gen_lm_cfg() -> LmConfig {
    LmConfig::new(
        24,
        StackConfig::hybrid(
            8,
            16,
            2,
            4,
            8,
            vec![MixerKind::Ovq { n_max: 16 }, MixerKind::SlidingWindow { window: 20 }],
        ),
    )
}

/// Run `sessions` generation requests through an LM engine and return
/// (completions keyed by session, the finished report).
fn run_generate(
    threads: usize,
    max_resident: usize,
    sessions: u64,
    params: &SamplingParams,
    stop: &StopCriteria,
) -> (HashMap<u64, Vec<u32>>, ovq::coordinator::engine::EngineReport) {
    let mut cfg = EngineConfig::for_lm(gen_lm_cfg());
    cfg.threads = threads;
    cfg.max_resident = max_resident;
    cfg.prefill_quantum = 16; // several quanta per 40-token prompt
    cfg.gen_quantum = 4; // several scheduling rounds per completion
    let engine = DecodeEngine::start(cfg);
    for s in 0..sessions {
        let prompt = traffic::synth_tokens(0x6E7, s, 40, 24);
        engine.submit_generate(s, prompt, params.clone(), stop.clone());
    }
    let report = engine.finish();
    let outs = report.generations.iter().map(|g| (g.session, g.tokens.clone())).collect();
    (outs, report)
}

#[test]
fn greedy_generation_is_bit_identical_across_threads_and_eviction() {
    // the acceptance golden, parts (a) and (b): greedy generation from a
    // fixed seed must produce identical token streams across (a) 1 vs 4
    // shard threads and (b) with vs without mid-generation eviction under
    // max_resident = 1 — six concurrent sessions on one shard guarantee
    // every scheduling round swaps residency, so each session's history
    // ring, RNG and stack state churn through snapshot blobs repeatedly
    // while its completion is still being sampled
    let stop = StopCriteria::max_new(24);
    let (base, r1) = run_generate(1, 64, 6, &SamplingParams::greedy(), &stop);
    assert_eq!(r1.completions(), 6);
    assert_eq!(r1.evictions(), 0, "uncapped run must not evict");
    for (s, toks) in &base {
        assert_eq!(toks.len(), 24, "session {s} under-generated");
        assert!(toks.iter().all(|&t| (t as usize) < 24));
    }

    let (threaded, r4) = run_generate(4, 64, 6, &SamplingParams::greedy(), &stop);
    assert_eq!(r4.completions(), 6);
    assert_eq!(base, threaded, "thread count changed a greedy completion");

    let (churned, rc) = run_generate(1, 1, 6, &SamplingParams::greedy(), &stop);
    assert!(rc.evictions() > 0, "cap 1 with 6 sessions must churn mid-generation");
    assert!(rc.restores() > 0);
    assert_eq!(base, churned, "mid-generation eviction changed a completion");
}

#[test]
fn sampled_generation_replays_deterministically_under_churn() {
    // categorical sampling (temperature + top-k + top-p + repetition
    // penalty) with a fixed request seed: the full sampler state — RNG
    // mid-stream and penalty history ring — must survive snapshot churn
    // and thread-count changes, token for token
    let params = SamplingParams::sampled(0xD1E5);
    let stop = StopCriteria::max_new(20);
    let (base, _) = run_generate(1, 64, 5, &params, &stop);
    assert!(base.values().any(|t| t.windows(2).any(|w| w[0] != w[1])), "sampling should mix");
    let (threaded, _) = run_generate(4, 64, 5, &params, &stop);
    assert_eq!(base, threaded, "thread count changed a sampled completion");
    let (churned, rc) = run_generate(1, 1, 5, &params, &stop);
    assert!(rc.evictions() > 0);
    assert_eq!(base, churned, "eviction changed a sampled completion");
}

#[test]
fn stop_tokens_truncate_the_completion() {
    // take an unconstrained greedy completion, then rerun with its 5th
    // token as a stop token: the rerun must emit exactly the first 5
    // tokens (stop token included) and nothing after
    let stop = StopCriteria::max_new(24);
    let (base, _) = run_generate(1, 64, 1, &SamplingParams::greedy(), &stop);
    let full = &base[&0];
    let stop_tok = full[4];
    // the stop token must not appear earlier, or the rerun stops sooner —
    // pick the FIRST occurrence index to make the expectation exact
    let first_at = full.iter().position(|&t| t == stop_tok).unwrap();
    let stop = StopCriteria::max_new(24).with_stop_tokens(vec![stop_tok]);
    let (cut, r) = run_generate(1, 64, 1, &SamplingParams::greedy(), &stop);
    assert_eq!(cut[&0][..], full[..first_at + 1], "completion must end AT the stop token");
    assert_eq!(r.gen_tokens(), first_at + 1);
}

#[test]
fn generation_interleaves_with_decode_and_prefill_traffic() {
    // the three workloads coexist on one shard: a generating session, a
    // plain-decode session, and a long-prompt prefill session. Everything
    // completes, per-session ordering holds across the generate boundary,
    // and the decode stream is bit-identical to a generation-free run.
    let d = 8;
    let mk_cfg = || {
        let mut cfg = EngineConfig::for_lm(gen_lm_cfg());
        cfg.threads = 1;
        cfg.prefill_quantum = 32;
        cfg.gen_quantum = 4;
        cfg.collect_outputs = true;
        cfg
    };
    let (gen_s, dec_s, pre_s) = (1u64, 2u64, 3u64);

    let engine = DecodeEngine::start(mk_cfg());
    engine.submit_generate(
        gen_s,
        traffic::synth_tokens(1, gen_s, 64, 24),
        SamplingParams::greedy(),
        StopCriteria::max_new(16),
    );
    for seq in 0..4usize {
        engine.submit(dec_s, traffic::synth_chunk(0xDC, dec_s, seq, 8, d));
    }
    engine.submit_prefill(pre_s, traffic::synth_chunk(0xBB, pre_s, 0, 128, d));
    // a decode chunk for the GENERATING session, submitted mid-request:
    // must defer behind the whole generation and still process
    engine.submit(gen_s, traffic::synth_chunk(0xDC, gen_s, 99, 8, d));
    engine.flush_all();
    let mixed = engine.finish();

    assert_eq!(mixed.completions(), 1);
    assert_eq!(mixed.generations[0].tokens.len(), 16);
    assert_eq!(mixed.prefill_chunks(), 1, "the plain prompt completed");
    let shard = &mixed.shards[0];
    assert!(shard.gen_busy > std::time::Duration::ZERO);
    assert!(shard.prefill_busy > std::time::Duration::ZERO);
    assert!(shard.busy > shard.gen_busy + shard.prefill_busy, "decode share visible");
    // the deferred decode chunk for the generating session ran after the
    // generation (seq 1 = the generate request, seq 2 = the chunk)
    assert_eq!(mixed.generations[0].seq, 1);
    let late = mixed
        .outputs
        .iter()
        .find(|o| o.session == gen_s)
        .expect("deferred chunk processed");
    assert_eq!(late.seq, 2);

    // generation-free mirror: the decode session must not feel the
    // generating neighbour at all
    let engine = DecodeEngine::start(mk_cfg());
    for seq in 0..4usize {
        engine.submit(dec_s, traffic::synth_chunk(0xDC, dec_s, seq, 8, d));
    }
    engine.flush_all();
    let plain = engine.finish();
    let pick = |r: &ovq::coordinator::engine::EngineReport| -> Vec<(usize, Vec<u32>)> {
        let mut v: Vec<(usize, Vec<u32>)> = r
            .outputs
            .iter()
            .filter(|o| o.session == dec_s)
            .map(|o| (o.seq, o.out.iter().map(|x| x.to_bits()).collect()))
            .collect();
        v.sort();
        v
    };
    assert_eq!(pick(&mixed), pick(&plain), "a neighbour's generation changed decode bits");
}

// ---------------------------------------------------------- tiered memory

#[test]
fn spill_and_prefix_fork_matrix_is_bit_identical_under_churn() {
    // the tiered-memory acceptance golden: the same generation workload —
    // a 64-token shared system prefix opening six sessions, then two more
    // rounds of per-session turns under max_resident = 1 churn — must
    // produce bit-identical completions with disk spill + prefix forking
    // ON vs OFF, at 1 vs 4 shard threads. The sleeps between rounds let
    // the async writeback land, so the 1-thread tiered run deterministically
    // restores from the disk tier rather than catching blobs still in RAM.
    use ovq::ovqcore::store::TempDir;
    let prefix: Vec<u32> = (0..64u32).map(|i| (i * 7 + 5) % 24).collect();
    let run = |threads: usize, tiered: bool| {
        let dir = tiered.then(|| TempDir::new("tiered-matrix"));
        let mut cfg = EngineConfig::for_lm(gen_lm_cfg());
        cfg.threads = threads;
        cfg.max_resident = 1;
        cfg.prefill_quantum = 64; // the whole prefix fits one quantum
        cfg.gen_quantum = 4;
        cfg.prefix_cache = tiered;
        if let Some(d) = &dir {
            cfg.spill_dir = Some(d.path().to_path_buf());
            cfg.ram_blob_budget = 0; // every evicted blob heads to disk
        }
        let engine = DecodeEngine::start(cfg);
        for round in 0..3usize {
            for s in 0..6u64 {
                let (prompt, plen) = if round == 0 {
                    let mut p = prefix.clone();
                    p.extend(traffic::synth_tokens(0x7E4, s, 8, 24));
                    let plen = prefix.len();
                    (p, plen)
                } else {
                    (traffic::synth_tokens(0x7E4 + round as u64, s, 8, 24), 0)
                };
                engine.submit_generate_prefixed(
                    s,
                    prompt,
                    plen,
                    None,
                    SamplingParams::greedy(),
                    StopCriteria::max_new(12),
                );
            }
            std::thread::sleep(std::time::Duration::from_millis(150));
        }
        let report = engine.finish();
        let mut outs: HashMap<u64, Vec<(usize, Vec<u32>)>> = HashMap::new();
        for g in &report.generations {
            outs.entry(g.session).or_default().push((g.seq, g.tokens.clone()));
        }
        outs.values_mut().for_each(|v| v.sort());
        (outs, report, dir)
    };

    let (base, rb, _) = run(1, false);
    assert_eq!(rb.completions(), 18, "3 rounds x 6 sessions");
    assert_eq!(rb.prefix_forks(), 0, "cache off must never fork");
    assert_eq!(rb.spills(), 0, "no spill dir, no spills");
    for threads in [1usize, 4] {
        let (tiered, rt, _dir) = run(threads, true);
        assert_eq!(
            tiered, base,
            "spill + prefix forking changed a completion at {threads} threads"
        );
        assert_eq!(rt.completions(), 18);
        if threads == 1 {
            // one shard, prefix inside the first quantum: session 0 builds
            // the template, sessions 1..=5 fork it — the count is exact
            assert_eq!(rt.prefix_forks(), 5);
            assert_eq!(rt.prefix_fork_tokens(), 5 * prefix.len());
            assert!(rt.spills() >= 1, "budget 0 under churn must spill");
            assert!(rt.disk_restores() >= 1, "later rounds must thaw from disk");
        }
    }
}

#[test]
fn spilled_sessions_cost_an_index_entry_of_ram() {
    // eviction-accounting satellite: once a session's blob is on disk its
    // RAM cost must drop to the store's per-entry index bookkeeping —
    // cross-checked EXACTLY against the store's own constant
    use ovq::ovqcore::store::{TempDir, INDEX_ENTRY_BYTES};
    let dir = TempDir::new("spill-accounting");
    let mut cfg = EngineConfig::new(MixerKind::Ovq { n_max: 32 }, 2, 8, 16);
    cfg.threads = 1;
    cfg.max_resident = 1;
    cfg.spill_dir = Some(dir.path().to_path_buf());
    cfg.ram_blob_budget = 0;
    let engine = DecodeEngine::start(cfg);
    let hd = engine.heads() * engine.d_head();
    for round in 0..3usize {
        for session in [0u64, 1, 2] {
            engine.submit(session, traffic::synth_chunk(1, session, round, 8, hd));
        }
        // let the writeback drain so every frozen blob really leaves RAM
        std::thread::sleep(std::time::Duration::from_millis(150));
    }
    let report = engine.finish();
    let shard = &report.shards[0];
    assert_eq!(shard.sessions, 3);
    assert!(shard.spills >= 2, "two of three sessions are always frozen");
    // finish() syncs the store, so at shutdown every non-resident blob is
    // on disk: snapshot accounting must be exactly index entries
    assert_eq!(shard.disk_sessions, 2, "cap 1 leaves two sessions frozen");
    assert!(shard.disk_bytes > 0);
    assert_eq!(
        shard.snapshot_bytes,
        2 * INDEX_ENTRY_BYTES,
        "a spilled session must cost an index entry of RAM, not its blob"
    );
}

// ------------------------------------------------------------ backpressure

/// A deliberately slow mixer: delegates to GDN but sleeps per chunk, so a
/// shard's queue fills while the submitter keeps offering load.
struct SlowMixer {
    inner: GdnState,
    delay: std::time::Duration,
}

impl SeqMixer for SlowMixer {
    fn kind_name(&self) -> &'static str {
        "gdn" // snapshots thaw as plain GDN; fine — tests never restore these
    }

    fn d_in(&self) -> usize {
        self.inner.d_in()
    }

    fn d_out(&self) -> usize {
        self.inner.d_out()
    }

    fn tokens(&self) -> usize {
        self.inner.tokens()
    }

    fn state_bytes(&self) -> usize {
        self.inner.state_bytes()
    }

    fn update_bytes_per_chunk(&self, l: usize) -> usize {
        self.inner.update_bytes_per_chunk(l)
    }

    fn write(&mut self, k: &[f32], v: &[f32]) {
        std::thread::sleep(self.delay);
        self.inner.write(k, v);
    }

    fn read(&self, q: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        self.inner.read(q, out, scratch);
    }

    fn snapshot(&self, w: &mut snapshot::Writer) {
        self.inner.snapshot(w);
    }
}

#[test]
fn slow_shard_queue_never_exceeds_bound() {
    // satellite: a slow shard must convert overload into submit-side
    // blocking, not queue growth. queue_depth=2 means at most 2 queued +
    // 1 in service + 1 blocked submitter ever counted by the gauge.
    let depth = 2usize;
    let mut cfg = EngineConfig::new(MixerKind::Gdn, 1, 4, 8);
    cfg.threads = 1;
    cfg.queue_depth = depth;
    let engine = DecodeEngine::start_with(cfg, |_, _| {
        Box::new(SlowMixer {
            inner: GdnState::new(4),
            delay: std::time::Duration::from_millis(2),
        })
    });
    for i in 0..12usize {
        engine.submit(7, traffic::synth_chunk(3, 7, i, 2, 4));
    }
    let report = engine.finish();
    assert_eq!(report.chunks, 12, "all offered chunks served");
    let shard = &report.shards[0];
    assert!(
        shard.max_queue <= depth + 2,
        "queue high-water {} exceeded bound {} + in-service + submitter",
        shard.max_queue,
        depth
    );
    assert!(shard.max_queue >= depth, "test never actually filled the queue");
}
