//! Integration tests for the sharded decode engine: the multi-thread
//! bit-identity golden cross-check, scheduler fairness under equal
//! offered load, bounded-queue backpressure, and the eviction/restore
//! accounting contract. All offline (tier-1) — no artifacts or PJRT.

use std::collections::HashMap;

use ovq::coordinator::engine::{DecodeEngine, EngineConfig, EngineOut};
use ovq::coordinator::traffic::{self, TrafficConfig};
use ovq::ovqcore::bank::{DecodeChunk, MixerBank};
use ovq::ovqcore::memstate::MixerKind;
use ovq::ovqcore::mixer::{Scratch, SeqMixer};
use ovq::ovqcore::{gdn::GdnState, snapshot};
use ovq::util::rng::Rng;

/// Run a trace through an engine with `threads` workers and return every
/// output keyed by (session, seq).
fn run_trace(
    threads: usize,
    max_resident: usize,
    events: &[ovq::coordinator::traffic::TrafficEvent],
) -> HashMap<(u64, usize), Vec<f32>> {
    let mut cfg = EngineConfig::new(MixerKind::Ovq { n_max: 32 }, 2, 8, 16);
    cfg.threads = threads;
    cfg.max_resident = max_resident;
    cfg.queue_depth = 8;
    cfg.collect_outputs = true;
    let engine = DecodeEngine::start(cfg);
    let mut sink = Vec::new();
    traffic::replay(&engine, events, 0xDA7A, Some(&mut sink));
    engine.flush_all();
    let report = engine.finish();
    sink.extend(report.outputs);
    sink.into_iter().map(|EngineOut { session, seq, out }| ((session, seq), out)).collect()
}

#[test]
fn multi_thread_output_bit_identical_to_single_thread() {
    // the tentpole's golden cross-check: the same zipf trace through 1, 2
    // and 4 worker threads — with a residency cap tight enough to force
    // evict/restore churn — must produce bit-identical outputs per stream
    let mut tcfg = TrafficConfig::new(12, 120);
    tcfg.chunk_sizes = vec![1, 4, 16];
    let events = traffic::generate(&tcfg);
    let single = run_trace(1, 3, &events);
    assert!(!single.is_empty());
    for threads in [2usize, 4] {
        let multi = run_trace(threads, 3, &events);
        assert_eq!(single.len(), multi.len(), "{threads} threads lost outputs");
        for (key, out) in &single {
            let got = multi
                .get(key)
                .unwrap_or_else(|| panic!("{threads} threads missing chunk {key:?}"));
            assert!(
                out.iter().zip(got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "outputs for session {} chunk {} differ at {} threads",
                key.0,
                key.1,
                threads
            );
        }
    }
}

#[test]
fn eviction_churn_matches_uncapped_run() {
    // snapshot/restore must be invisible to the streams: a run whose
    // sessions constantly bounce through eviction (cap 1) must equal the
    // run where every session stays resident
    let mut tcfg = TrafficConfig::new(6, 60);
    tcfg.seed = 0x5E55;
    let events = traffic::generate(&tcfg);
    let roomy = run_trace(1, 64, &events);
    let cramped = run_trace(1, 1, &events);
    assert_eq!(roomy.len(), cramped.len());
    for (key, out) in &roomy {
        assert_eq!(out, &cramped[key], "eviction changed session {} chunk {}", key.0, key.1);
    }
}

#[test]
fn engine_reports_eviction_accounting() {
    // cap 1 on a single shard: with two interleaved sessions every
    // arrival swaps residency, so at shutdown one session is resident and
    // one is a snapshot blob — and the accounting must say exactly that
    let mut cfg = EngineConfig::new(MixerKind::Ovq { n_max: 32 }, 2, 8, 16);
    cfg.threads = 1;
    cfg.max_resident = 1;
    let engine = DecodeEngine::start(cfg);
    let hd = engine.heads() * engine.d_head();
    for round in 0..4usize {
        for session in [0u64, 1] {
            engine.submit(session, traffic::synth_chunk(1, session, round, 8, hd));
        }
    }
    let report = engine.finish();
    let shard = &report.shards[0];
    assert!(shard.evictions >= 7, "expected swap churn, got {}", shard.evictions);
    assert!(shard.restores >= 6, "expected restores, got {}", shard.restores);
    assert_eq!(shard.sessions, 2);
    assert!(shard.resident_bytes > 0, "one session stays live");
    assert!(shard.snapshot_bytes > 0, "one session is frozen to a blob");
    // the frozen session's accounted bytes are exactly the blob: rebuild
    // the blob size bound from a same-shape mixer snapshot
    let probe: Box<dyn SeqMixer> = MixerKind::Ovq { n_max: 32 }.build(8, 16, 1);
    let empty_blob = snapshot::save(probe.as_ref());
    assert!(
        shard.snapshot_bytes >= empty_blob.len(),
        "blob accounting below the framing floor"
    );
}

#[test]
fn explicit_evict_is_invisible_to_the_stream() {
    // the engine-level abandon API: chunks, evict, more chunks — the
    // eviction must be counted, must freeze real bytes, and must not
    // change a single output bit vs the run that never evicted
    let mk_cfg = || {
        let mut cfg = EngineConfig::new(MixerKind::Ovq { n_max: 32 }, 2, 8, 16);
        cfg.threads = 1;
        cfg.collect_outputs = true;
        cfg
    };
    let run = |evict: bool| {
        let engine = DecodeEngine::start(mk_cfg());
        let hd = engine.heads() * engine.d_head();
        for round in 0..2usize {
            engine.submit(5, traffic::synth_chunk(7, 5, round, 10, hd));
        }
        if evict {
            engine.evict(5);
        }
        for round in 2..4usize {
            engine.submit(5, traffic::synth_chunk(7, 5, round, 10, hd));
        }
        engine.finish()
    };
    let plain = run(false);
    let evicted = run(true);
    assert_eq!(evicted.shards[0].evictions, 1);
    assert_eq!(evicted.shards[0].restores, 1);
    assert_eq!(plain.shards[0].evictions, 0);
    assert_eq!(evicted.outputs.len(), 4);
    for (a, b) in plain.outputs.iter().zip(&evicted.outputs) {
        assert_eq!((a.session, a.seq), (b.session, b.seq));
        assert!(
            a.out.iter().zip(&b.out).all(|(x, y)| x.to_bits() == y.to_bits()),
            "evict/restore changed chunk {} of the stream",
            a.seq
        );
    }
}

#[test]
fn equal_offered_load_is_served_fairly_mid_run() {
    // satellite: with equal offered load, no stream's completed-token
    // count may lag the median by more than one chunk — checked mid-drain
    // on the round-robin bank at several points
    let (streams, d, chunk_len) = (5usize, 8usize, 16usize);
    let mut rng = Rng::new(21);
    let mut bank = MixerBank::new(streams, 1, |_, _| {
        MixerKind::Ovq { n_max: 32 }.build(d, 16, 9)
    });
    let mut mk = |rng: &mut Rng| DecodeChunk {
        queries: (0..chunk_len * d).map(|_| rng.normal() as f32).collect(),
        keys: (0..chunk_len * d).map(|_| rng.normal() as f32).collect(),
        values: (0..chunk_len * d).map(|_| rng.normal() as f32).collect(),
    };
    for _ in 0..4 {
        for s in 0..streams {
            let c = mk(&mut rng);
            bank.submit(s, c);
        }
    }
    let total = 4 * streams;
    for step in 0..total {
        bank.step().expect("queued work remains");
        let mut tokens: Vec<usize> = bank.stats.iter().map(|st| st.tokens).collect();
        tokens.sort_unstable();
        let median = tokens[tokens.len() / 2];
        for (s, st) in bank.stats.iter().enumerate() {
            assert!(
                st.tokens + chunk_len >= median,
                "step {step}: stream {s} at {} tokens lags median {median} by more \
                 than one chunk",
                st.tokens
            );
        }
    }
}

#[test]
fn engine_equal_load_completes_equally() {
    // end-state fairness through the threaded engine: equal offered load,
    // equal completions — no session starves on any shard
    let mut cfg = EngineConfig::new(MixerKind::Gdn, 2, 8, 16);
    cfg.threads = 4;
    let engine = DecodeEngine::start(cfg);
    let hd = engine.heads() * engine.d_head();
    for round in 0..5usize {
        for session in 0..9u64 {
            engine.submit(session, traffic::synth_chunk(2, session, round, 8, hd));
        }
    }
    let report = engine.finish();
    assert_eq!(report.sessions.len(), 9);
    for (id, st) in &report.sessions {
        assert_eq!(st.tokens, 5 * 8, "session {id} under-served");
        assert_eq!(st.chunks, 5);
    }
}

// ------------------------------------------------------------ backpressure

/// A deliberately slow mixer: delegates to GDN but sleeps per chunk, so a
/// shard's queue fills while the submitter keeps offering load.
struct SlowMixer {
    inner: GdnState,
    delay: std::time::Duration,
}

impl SeqMixer for SlowMixer {
    fn kind_name(&self) -> &'static str {
        "gdn" // snapshots thaw as plain GDN; fine — tests never restore these
    }

    fn d_in(&self) -> usize {
        self.inner.d_in()
    }

    fn d_out(&self) -> usize {
        self.inner.d_out()
    }

    fn tokens(&self) -> usize {
        self.inner.tokens()
    }

    fn state_bytes(&self) -> usize {
        self.inner.state_bytes()
    }

    fn update_bytes_per_chunk(&self, l: usize) -> usize {
        self.inner.update_bytes_per_chunk(l)
    }

    fn write(&mut self, k: &[f32], v: &[f32]) {
        std::thread::sleep(self.delay);
        self.inner.write(k, v);
    }

    fn read(&self, q: &[f32], out: &mut [f32], scratch: &mut Scratch) {
        self.inner.read(q, out, scratch);
    }

    fn snapshot(&self, w: &mut snapshot::Writer) {
        self.inner.snapshot(w);
    }
}

#[test]
fn slow_shard_queue_never_exceeds_bound() {
    // satellite: a slow shard must convert overload into submit-side
    // blocking, not queue growth. queue_depth=2 means at most 2 queued +
    // 1 in service + 1 blocked submitter ever counted by the gauge.
    let depth = 2usize;
    let mut cfg = EngineConfig::new(MixerKind::Gdn, 1, 4, 8);
    cfg.threads = 1;
    cfg.queue_depth = depth;
    let engine = DecodeEngine::start_with(cfg, |_, _| {
        Box::new(SlowMixer {
            inner: GdnState::new(4),
            delay: std::time::Duration::from_millis(2),
        })
    });
    for i in 0..12usize {
        engine.submit(7, traffic::synth_chunk(3, 7, i, 2, 4));
    }
    let report = engine.finish();
    assert_eq!(report.chunks, 12, "all offered chunks served");
    let shard = &report.shards[0];
    assert!(
        shard.max_queue <= depth + 2,
        "queue high-water {} exceeded bound {} + in-service + submitter",
        shard.max_queue,
        depth
    );
    assert!(shard.max_queue >= depth, "test never actually filled the queue");
}
