//! Streaming-equivalence golden tests for the SeqMixer state machines —
//! the test rust/src/ovqcore/ovq.rs promises: the same token stream fed
//! token-by-token (arrival chunk 1) and in chunks (arrival chunk 16)
//! through the trait interface must produce identical outputs and
//! identical final state, for OVQ and for every other mixer. Runs
//! entirely on the pure-Rust path — no artifacts or PJRT backend needed.

use ovq::ovqcore::memstate::MixerKind;
use ovq::ovqcore::mixer::{Scratch, SeqMixer};
use ovq::ovqcore::ovq::{OvqConfig, OvqState};
use ovq::util::prop::Prop;
use ovq::util::rng::Rng;

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Feed `total` tokens in arrival chunks of `arrival`, collecting every
/// output row. `arrival` is the *delivery* granularity; the mixer's own
/// chunk length is part of its config and unchanged.
fn stream_through(
    m: &mut dyn SeqMixer,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    total: usize,
    arrival: usize,
) -> Vec<f32> {
    let d = m.d_in();
    let dv = m.d_out();
    let mut out = vec![0.0f32; total * dv];
    let mut scratch = Scratch::new();
    let mut i = 0;
    while i < total {
        let len = arrival.min(total - i);
        m.process_chunk(
            &q[i * d..(i + len) * d],
            &k[i * d..(i + len) * d],
            &v[i * dv..(i + len) * dv],
            &mut out[i * dv..(i + len) * dv],
            &mut scratch,
        );
        i += len;
    }
    out
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn ovq_chunked_matches_token_by_token() {
    // the doc-comment contract: chunk=1 vs chunk=16 arrival, same inputs,
    // matching outputs (within fp tolerance) and identical growth
    let (d, n_max, chunk, total) = (16usize, 64usize, 16usize, 96usize);
    let mut rng = Rng::new(42);
    let q = randv(&mut rng, total * d);
    let k = randv(&mut rng, total * d);
    let v = randv(&mut rng, total * d);

    let mut one = OvqState::new(OvqConfig::new(d, n_max, chunk));
    let mut sixteen = OvqState::new(OvqConfig::new(d, n_max, chunk));
    let out_one = stream_through(&mut one, &q, &k, &v, total, 1);
    let out_sixteen = stream_through(&mut sixteen, &q, &k, &v, total, 16);

    let diff = max_abs_diff(&out_one, &out_sixteen);
    assert!(diff < 1e-5, "outputs diverged: max |Δ| = {diff}");

    one.flush();
    sixteen.flush();
    assert_eq!(one.n_active, sixteen.n_active, "growth must not depend on arrival");
    assert_eq!(one.t, sixteen.t);
    let sdiff = max_abs_diff(&one.dk, &sixteen.dk).max(max_abs_diff(&one.dv, &sixteen.dv));
    assert!(sdiff < 1e-5, "states diverged: max |Δ| = {sdiff}");
}

#[test]
fn prop_arrival_chunking_is_invisible_for_all_mixers() {
    // every mixer kind, random shapes, random arrival granularities —
    // outputs must be independent of delivery chunking
    Prop::new(7).cases(24).check(|c| {
        let d = 4 + 2 * c.rng.usize_below(7); // even dims, 4..16
        let chunk = 4 + c.rng.usize_below(13);
        let total = chunk * (2 + c.rng.usize_below(3)) + c.rng.usize_below(chunk);
        let arrival = 1 + c.rng.usize_below(2 * chunk);
        let kinds = [
            MixerKind::Ovq { n_max: 8 + c.rng.usize_below(64) },
            MixerKind::Vq { n: 4 + c.rng.usize_below(16) },
            MixerKind::LinearAttention,
            MixerKind::Gdn,
            MixerKind::FullAttention,
            MixerKind::SlidingWindow { window: 1 + c.rng.usize_below(total) },
        ];
        let q: Vec<f32> = (0..total * d).map(|_| c.rng.normal() as f32).collect();
        let k: Vec<f32> = (0..total * d).map(|_| c.rng.normal() as f32).collect();
        let v: Vec<f32> = (0..total * d).map(|_| c.rng.normal() as f32).collect();
        for kind in kinds {
            let mut a = kind.build(d, chunk, 3);
            let mut b = kind.build(d, chunk, 3);
            let out_a = stream_through(a.as_mut(), &q, &k, &v, total, 1);
            let out_b = stream_through(b.as_mut(), &q, &k, &v, total, arrival);
            let diff = max_abs_diff(&out_a, &out_b);
            if diff > 1e-4 {
                return Err(format!(
                    "{:?} d={d} chunk={chunk} total={total} arrival={arrival}: |Δ|={diff}",
                    kind
                ));
            }
            if a.tokens() != b.tokens() {
                return Err(format!("{:?}: token counts diverged", kind));
            }
            a.flush();
            b.flush();
            if a.state_bytes() != b.state_bytes() {
                return Err(format!("{:?}: state sizes diverged", kind));
            }
        }
        Ok(())
    });
}

#[test]
fn ovq_growth_matches_analytical_schedule_through_trait() {
    // streaming through the trait must hit the same N_t = t*N/(t+N)
    // growth the direct update_chunk path satisfies
    let (d, n_max, chunk) = (8usize, 128usize, 32usize);
    let mut rng = Rng::new(9);
    let mut st = OvqState::new(OvqConfig::new(d, n_max, chunk));
    let mut scratch = Scratch::new();
    let mut out = vec![0.0f32; d];
    for t in 1..=(chunk * 12) {
        let k = randv(&mut rng, d);
        let v = randv(&mut rng, d);
        let q = randv(&mut rng, d);
        st.write(&k, &v);
        st.read(&q, &mut out, &mut scratch);
        assert_eq!(st.tokens(), t);
    }
    st.flush();
    assert_eq!(st.n_active, ovq::ovqcore::growth_n_t(chunk * 12, n_max));
}

#[test]
fn flush_is_idempotent_and_preserves_reads() {
    let (d, total) = (8usize, 40usize);
    let mut rng = Rng::new(5);
    let mut st = OvqState::new(OvqConfig::new(d, 32, 16));
    let mut scratch = Scratch::new();
    for _ in 0..total {
        let k = randv(&mut rng, d);
        let v = randv(&mut rng, d);
        st.write(&k, &v);
    }
    let q = randv(&mut rng, d);
    st.flush();
    let mut a = vec![0.0f32; d];
    st.read(&q, &mut a, &mut scratch);
    st.flush(); // second flush must be a no-op
    let mut b = vec![0.0f32; d];
    st.read(&q, &mut b, &mut scratch);
    assert_eq!(st.t, total);
    assert_eq!(a, b);
}
