//! Streaming-equivalence golden tests for the SeqMixer state machines —
//! the test rust/src/ovqcore/ovq.rs promises: the same token stream fed
//! token-by-token (arrival chunk 1) and in chunks (arrival chunk 16)
//! through the trait interface must produce identical outputs and
//! identical final state, for OVQ and for every other mixer. Plus the
//! session-lifecycle contract: snapshot → restore → continue must be
//! **token-identical** (bit-exact, not tolerance-equal) to an
//! uninterrupted run, for every mixer, at arbitrary interruption points —
//! including mid-chunk, where OVQ has a buffered pending tail. Runs
//! entirely on the pure-Rust path — no artifacts or PJRT backend needed.

use ovq::ovqcore::memstate::MixerKind;
use ovq::ovqcore::mixer::{Scratch, SeqMixer};
use ovq::ovqcore::ovq::{OvqConfig, OvqState};
use ovq::ovqcore::snapshot;
use ovq::ovqcore::stack::{mixer_seed, LayerStack, StackConfig};
use ovq::util::prop::Prop;
use ovq::util::rng::Rng;

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Feed `total` tokens in arrival chunks of `arrival`, collecting every
/// output row. `arrival` is the *delivery* granularity; the mixer's own
/// chunk length is part of its config and unchanged.
fn stream_through(
    m: &mut dyn SeqMixer,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    total: usize,
    arrival: usize,
) -> Vec<f32> {
    let d = m.d_in();
    let dv = m.d_out();
    let mut out = vec![0.0f32; total * dv];
    let mut scratch = Scratch::new();
    let mut i = 0;
    while i < total {
        let len = arrival.min(total - i);
        m.process_chunk(
            &q[i * d..(i + len) * d],
            &k[i * d..(i + len) * d],
            &v[i * dv..(i + len) * dv],
            &mut out[i * dv..(i + len) * dv],
            &mut scratch,
        );
        i += len;
    }
    out
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn ovq_chunked_matches_token_by_token() {
    // the doc-comment contract: chunk=1 vs chunk=16 arrival, same inputs,
    // matching outputs (within fp tolerance) and identical growth
    let (d, n_max, chunk, total) = (16usize, 64usize, 16usize, 96usize);
    let mut rng = Rng::new(42);
    let q = randv(&mut rng, total * d);
    let k = randv(&mut rng, total * d);
    let v = randv(&mut rng, total * d);

    let mut one = OvqState::new(OvqConfig::new(d, n_max, chunk));
    let mut sixteen = OvqState::new(OvqConfig::new(d, n_max, chunk));
    let out_one = stream_through(&mut one, &q, &k, &v, total, 1);
    let out_sixteen = stream_through(&mut sixteen, &q, &k, &v, total, 16);

    let diff = max_abs_diff(&out_one, &out_sixteen);
    assert!(diff < 1e-5, "outputs diverged: max |Δ| = {diff}");

    one.flush();
    sixteen.flush();
    assert_eq!(one.n_active, sixteen.n_active, "growth must not depend on arrival");
    assert_eq!(one.t, sixteen.t);
    let sdiff = max_abs_diff(&one.dk.to_f32_vec(), &sixteen.dk.to_f32_vec())
        .max(max_abs_diff(&one.dv.to_f32_vec(), &sixteen.dv.to_f32_vec()));
    assert!(sdiff < 1e-5, "states diverged: max |Δ| = {sdiff}");
}

#[test]
fn prop_arrival_chunking_is_invisible_for_all_mixers() {
    // every mixer kind, random shapes, random arrival granularities —
    // outputs must be independent of delivery chunking
    Prop::new(7).cases(24).check(|c| {
        let d = 4 + 2 * c.rng.usize_below(7); // even dims, 4..16
        let chunk = 4 + c.rng.usize_below(13);
        let total = chunk * (2 + c.rng.usize_below(3)) + c.rng.usize_below(chunk);
        let arrival = 1 + c.rng.usize_below(2 * chunk);
        let kinds = [
            MixerKind::Ovq { n_max: 8 + c.rng.usize_below(64) },
            MixerKind::Vq { n: 4 + c.rng.usize_below(16) },
            MixerKind::LinearAttention,
            MixerKind::Gdn,
            MixerKind::FullAttention,
            MixerKind::SlidingWindow { window: 1 + c.rng.usize_below(total) },
        ];
        let q: Vec<f32> = (0..total * d).map(|_| c.rng.normal() as f32).collect();
        let k: Vec<f32> = (0..total * d).map(|_| c.rng.normal() as f32).collect();
        let v: Vec<f32> = (0..total * d).map(|_| c.rng.normal() as f32).collect();
        for kind in kinds {
            let mut a = kind.build(d, chunk, 3);
            let mut b = kind.build(d, chunk, 3);
            let out_a = stream_through(a.as_mut(), &q, &k, &v, total, 1);
            let out_b = stream_through(b.as_mut(), &q, &k, &v, total, arrival);
            let diff = max_abs_diff(&out_a, &out_b);
            if diff > 1e-4 {
                return Err(format!(
                    "{:?} d={d} chunk={chunk} total={total} arrival={arrival}: |Δ|={diff}",
                    kind
                ));
            }
            if a.tokens() != b.tokens() {
                return Err(format!("{:?}: token counts diverged", kind));
            }
            a.flush();
            b.flush();
            if a.state_bytes() != b.state_bytes() {
                return Err(format!("{:?}: state sizes diverged", kind));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_snapshot_restore_continue_is_token_identical_for_all_mixers() {
    // interrupt a decode at a random point, freeze the session to bytes,
    // thaw a fresh machine from them, and keep decoding both — every
    // subsequent output must be bit-identical, as must the final state.
    // This is what makes engine eviction invisible to the stream.
    Prop::new(31).cases(24).check(|c| {
        let d = 4 + 2 * c.rng.usize_below(7);
        let chunk = 4 + c.rng.usize_below(13);
        let total = chunk * 2 + c.rng.usize_below(3 * chunk);
        let cut = 1 + c.rng.usize_below(total - 1); // interrupt mid-stream
        let arrival = 1 + c.rng.usize_below(chunk); // delivery granularity
        let kinds = [
            MixerKind::Ovq { n_max: 8 + c.rng.usize_below(64) },
            MixerKind::Vq { n: 4 + c.rng.usize_below(16) },
            MixerKind::LinearAttention,
            MixerKind::Gdn,
            MixerKind::FullAttention,
            MixerKind::SlidingWindow { window: 1 + c.rng.usize_below(total) },
        ];
        let q: Vec<f32> = (0..total * d).map(|_| c.rng.normal() as f32).collect();
        let k: Vec<f32> = (0..total * d).map(|_| c.rng.normal() as f32).collect();
        let v: Vec<f32> = (0..total * d).map(|_| c.rng.normal() as f32).collect();
        for kind in kinds {
            // uninterrupted reference, fed the same delivery pattern as the
            // interrupted run (arrival chunks split at `cut`) so the ONLY
            // difference between the two runs is the freeze/thaw itself
            let rest = total - cut;
            let mut gold = kind.build(d, chunk, 3);
            let mut out_gold = stream_through(gold.as_mut(), &q, &k, &v, cut, arrival);
            out_gold.extend_from_slice(&stream_through(
                gold.as_mut(),
                &q[cut * d..],
                &k[cut * d..],
                &v[cut * d..],
                rest,
                arrival,
            ));

            // interrupted run: decode to `cut`, freeze, thaw, continue
            let mut a = kind.build(d, chunk, 3);
            let mut out = stream_through(a.as_mut(), &q, &k, &v, cut, arrival);
            let blob = snapshot::save(a.as_ref());
            let mut b = snapshot::restore(&blob)
                .map_err(|e| format!("{kind:?}: restore failed: {e}"))?;
            if b.tokens() != cut {
                return Err(format!("{kind:?}: thawed token count {}", b.tokens()));
            }
            let tail = stream_through(
                b.as_mut(),
                &q[cut * d..],
                &k[cut * d..],
                &v[cut * d..],
                rest,
                arrival,
            );
            out.extend_from_slice(&tail);

            // token-identical means bit-identical, not within-tolerance
            if out != out_gold {
                let i = out
                    .iter()
                    .zip(&out_gold)
                    .position(|(x, y)| x.to_bits() != y.to_bits())
                    .unwrap();
                return Err(format!(
                    "{kind:?} d={d} chunk={chunk} total={total} cut={cut} \
                     arrival={arrival}: outputs diverge at flat index {i} \
                     (token {}): {} vs {}",
                    i / d,
                    out[i],
                    out_gold[i]
                ));
            }
            gold.flush();
            b.flush();
            if gold.state_bytes() != b.state_bytes() || gold.tokens() != b.tokens() {
                return Err(format!("{kind:?}: final state diverged after restore"));
            }
            // and the format itself is stable: refreezing the thawed
            // machine at the cut must reproduce the blob... so freeze B
            // again after continuing and compare against the gold run
            if snapshot::save(b.as_ref()) != snapshot::save(gold.as_ref()) {
                return Err(format!("{kind:?}: continued snapshots diverged"));
            }
        }
        Ok(())
    });
}

/// Feed `total` tokens through [`SeqMixer::process_prefill`] in arrival
/// slices of `arrival` tokens.
fn prefill_through(
    m: &mut dyn SeqMixer,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    total: usize,
    arrival: usize,
) -> Vec<f32> {
    let d = m.d_in();
    let dv = m.d_out();
    let mut out = vec![0.0f32; total * dv];
    let mut scratch = Scratch::new();
    let mut i = 0;
    while i < total {
        let len = arrival.min(total - i);
        m.process_prefill(
            &q[i * d..(i + len) * d],
            &k[i * d..(i + len) * d],
            &v[i * dv..(i + len) * dv],
            &mut out[i * dv..(i + len) * dv],
            &mut scratch,
        );
        i += len;
    }
    out
}

#[test]
fn prop_prefill_is_bit_identical_to_serial_decode_for_all_mixers() {
    // the tentpole contract: the blocked process_prefill path must
    // reproduce token-at-a-time decode EXACTLY — same output bits, same
    // post-state snapshot — for every mixer, any block size, including
    // blocks cut mid-way through an OVQ pending tail
    Prop::new(91).cases(24).check(|c| {
        let d = 4 + 2 * c.rng.usize_below(7);
        let chunk = 4 + c.rng.usize_below(13);
        let total = chunk * (2 + c.rng.usize_below(3)) + c.rng.usize_below(chunk);
        // arrival slices deliberately misaligned with the mixer chunk so
        // prefill calls start and end inside pending tails
        let arrival = 1 + c.rng.usize_below(2 * chunk + 1);
        let kinds = [
            MixerKind::Ovq { n_max: 8 + c.rng.usize_below(64) },
            MixerKind::Vq { n: 4 + c.rng.usize_below(16) },
            MixerKind::LinearAttention,
            MixerKind::Gdn,
            MixerKind::FullAttention,
            MixerKind::SlidingWindow { window: 1 + c.rng.usize_below(total) },
        ];
        let q: Vec<f32> = (0..total * d).map(|_| c.rng.normal() as f32).collect();
        let k: Vec<f32> = (0..total * d).map(|_| c.rng.normal() as f32).collect();
        let v: Vec<f32> = (0..total * d).map(|_| c.rng.normal() as f32).collect();
        for kind in kinds {
            let mut serial = kind.build(d, chunk, 3);
            let mut blocked = kind.build(d, chunk, 3);
            let out_serial = stream_through(serial.as_mut(), &q, &k, &v, total, 1);
            let out_blocked = prefill_through(blocked.as_mut(), &q, &k, &v, total, arrival);
            if let Some(i) = out_serial
                .iter()
                .zip(&out_blocked)
                .position(|(a, b)| a.to_bits() != b.to_bits())
            {
                return Err(format!(
                    "{kind:?} d={d} chunk={chunk} total={total} arrival={arrival}: \
                     prefill diverges at flat index {i} (token {}): {} vs {}",
                    i / d,
                    out_blocked[i],
                    out_serial[i]
                ));
            }
            // post-state must be bit-identical too — including any OVQ
            // pending tail, which the snapshot serializes raw
            if snapshot::save(serial.as_ref()) != snapshot::save(blocked.as_ref()) {
                return Err(format!("{kind:?}: post-prefill snapshots diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn ovq_prefill_cut_mid_pending_tail_is_exact() {
    // the sharpest prefill corner, pinned deterministically: a prefill
    // block that ends mid-chunk leaves a pending tail; the next block
    // must pick it up, merge at the same boundary serial decode would,
    // and keep every output bit
    let (d, n_max, chunk) = (8usize, 32usize, 16usize);
    let total = 3 * chunk + chunk / 2; // 56: ends mid-tail
    let cut = chunk + chunk / 2 - 1; // 23: cuts mid-tail too
    let mut rng = Rng::new(1234);
    let q = randv(&mut rng, total * d);
    let k = randv(&mut rng, total * d);
    let v = randv(&mut rng, total * d);

    let mut serial = OvqState::new(OvqConfig::new(d, n_max, chunk));
    let out_serial = stream_through(&mut serial, &q, &k, &v, total, 1);

    let mut blocked = OvqState::new(OvqConfig::new(d, n_max, chunk));
    let mut scratch = Scratch::new();
    let mut out_blocked = vec![0.0f32; total * d];
    blocked.process_prefill(
        &q[..cut * d],
        &k[..cut * d],
        &v[..cut * d],
        &mut out_blocked[..cut * d],
        &mut scratch,
    );
    assert!(blocked.pending_len() > 0, "first block must leave a pending tail");
    blocked.process_prefill(
        &q[cut * d..],
        &k[cut * d..],
        &v[cut * d..],
        &mut out_blocked[cut * d..],
        &mut scratch,
    );
    assert!(blocked.pending_len() > 0, "stream ends mid-tail");
    for (i, (a, b)) in out_serial.iter().zip(&out_blocked).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "flat index {i} (token {})", i / d);
    }
    assert_eq!(snapshot::save(&serial), snapshot::save(&blocked));
}

// ------------------------------------------------------------------ stacks

#[test]
fn identity_stack_is_the_bare_mixer_bit_for_bit() {
    // the bare-mixer bridge: a 1-layer identity stack over any kind must
    // reproduce the standalone mixer exactly — decode path, prefill path,
    // token counts — proving LayerStack strictly generalizes PRs 1–3
    let (d, chunk, total) = (8usize, 16usize, 56usize);
    let kinds = [
        MixerKind::Ovq { n_max: 32 },
        MixerKind::Vq { n: 16 },
        MixerKind::LinearAttention,
        MixerKind::Gdn,
        MixerKind::FullAttention,
        MixerKind::SlidingWindow { window: 24 },
    ];
    let mut rng = Rng::new(0x57AC);
    let q = randv(&mut rng, total * d);
    let k = randv(&mut rng, total * d);
    let v = randv(&mut rng, total * d);
    for kind in kinds {
        let seed = 0xB0B;
        let mut stack = LayerStack::new(StackConfig::bare(kind, 1, d, chunk), seed);
        let mut bare = kind.build(d, chunk, mixer_seed(seed, 0, 0));
        let got = stream_through(&mut stack, &q, &k, &v, total, 13);
        let want = stream_through(bare.as_mut(), &q, &k, &v, total, 13);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{kind:?}: decode diverges at {i}");
        }
        assert_eq!(stack.tokens(), bare.tokens(), "{kind:?}");
        assert_eq!(stack.state_bytes(), bare.state_bytes(), "{kind:?}");

        let mut stack_p = LayerStack::new(StackConfig::bare(kind, 1, d, chunk), seed);
        let mut bare_p = kind.build(d, chunk, mixer_seed(seed, 0, 0));
        let got = prefill_through(&mut stack_p, &q, &k, &v, total, 19);
        let want = prefill_through(bare_p.as_mut(), &q, &k, &v, total, 19);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{kind:?}: prefill diverges at {i}");
        }
    }
}

/// Feed a full-mode stack `total` embedding rows (the keys stream) in
/// arrival chunks of `arrival` through `process_chunk`.
fn stack_decode(st: &mut LayerStack, x: &[f32], total: usize, arrival: usize) -> Vec<f32> {
    stream_through(st, x, x, x, total, arrival)
}

fn hybrid_cfg(layers: usize, chunk: usize) -> StackConfig {
    let kinds = (0..layers)
        .map(|l| match l % 3 {
            0 => MixerKind::Ovq { n_max: 24 },
            1 => MixerKind::SlidingWindow { window: 17 },
            _ => MixerKind::Gdn,
        })
        .collect();
    StackConfig::hybrid(8, 16, 2, 4, chunk, kinds)
}

#[test]
fn prop_stack_prefill_is_bit_identical_to_serial_stack_decode() {
    // the tentpole contract at the whole-model level: blocked prefill
    // through every dense op and mixer must reproduce token-at-a-time
    // stack decode exactly — outputs and post-state snapshots — for
    // hybrid schedules, any depth, any arrival slicing
    Prop::new(0x57A1).cases(12).check(|c| {
        let layers = 1 + c.rng.usize_below(3);
        let chunk = 4 + c.rng.usize_below(13);
        let total = chunk * (1 + c.rng.usize_below(3)) + c.rng.usize_below(chunk);
        let arrival = 1 + c.rng.usize_below(2 * chunk + 1);
        let cfg = hybrid_cfg(layers, chunk);
        let d = cfg.d_model;
        let x: Vec<f32> = (0..total * d).map(|_| c.rng.normal() as f32).collect();

        let mut serial = LayerStack::new(cfg.clone(), 5);
        let mut blocked = LayerStack::new(cfg, 5);
        let out_serial = stack_decode(&mut serial, &x, total, 1);
        let out_blocked = prefill_through(&mut blocked, &x, &x, &x, total, arrival);
        if let Some(i) = out_serial
            .iter()
            .zip(&out_blocked)
            .position(|(a, b)| a.to_bits() != b.to_bits())
        {
            return Err(format!(
                "layers={layers} chunk={chunk} total={total} arrival={arrival}: \
                 stack prefill diverges at flat index {i}"
            ));
        }
        if snapshot::save(&serial) != snapshot::save(&blocked) {
            return Err(format!(
                "layers={layers} chunk={chunk} total={total}: post-prefill \
                 stack snapshots diverged"
            ));
        }
        Ok(())
    });
}

#[test]
fn stack_snapshot_restore_continue_is_token_identical_mid_pending_tail() {
    // freeze a 3-layer hybrid stack mid-stream — with OVQ pending tails
    // buffered at layer depth > 1 — thaw through the container frame,
    // and keep decoding: every later output must match the uninterrupted
    // run to the bit
    let (chunk, total) = (16usize, 3 * 16 + 9);
    let cut = 16 + 7; // mid-chunk: pending tails are non-empty
    let cfg = hybrid_cfg(3, chunk);
    let d = cfg.d_model;
    let mut rng = Rng::new(0x5EED);
    let x = randv(&mut rng, total * d);

    let mut gold = LayerStack::new(cfg.clone(), 9);
    let mut out_gold = stack_decode(&mut gold, &x, cut, 5);
    out_gold.extend_from_slice(&stream_through(
        &mut gold,
        &x[cut * d..],
        &x[cut * d..],
        &x[cut * d..],
        total - cut,
        5,
    ));

    let mut a = LayerStack::new(cfg, 9);
    let mut out = stack_decode(&mut a, &x, cut, 5);
    let blob = snapshot::save(&a);
    let mut b = snapshot::restore(&blob).expect("stack blob must thaw");
    assert_eq!(b.kind_name(), "stack");
    assert_eq!(b.tokens(), cut);
    out.extend_from_slice(&stream_through(
        b.as_mut(),
        &x[cut * d..],
        &x[cut * d..],
        &x[cut * d..],
        total - cut,
        5,
    ));
    assert_eq!(out.len(), out_gold.len());
    for (i, (p, g)) in out.iter().zip(&out_gold).enumerate() {
        assert_eq!(p.to_bits(), g.to_bits(), "restore broke the stream at flat index {i}");
    }
    assert_eq!(snapshot::save(b.as_ref()), snapshot::save(&gold), "final snapshots diverged");
}

#[test]
fn snapshot_preserves_ovq_pending_tail_exactly() {
    // the sharpest corner: freeze with a partial chunk buffered (pending
    // tail not yet merged), thaw, and let the merge happen post-restore
    let (d, n_max, chunk) = (8usize, 32usize, 16usize);
    let mut rng = Rng::new(77);
    let mut a = OvqState::new(OvqConfig::new(d, n_max, chunk));
    let mut scratch = Scratch::new();
    let mut out = vec![0.0f32; d];
    for _ in 0..(chunk + chunk / 2) {
        // chunk-and-a-half: tail buffered
        let k = randv(&mut rng, d);
        let v = randv(&mut rng, d);
        a.write(&k, &v);
        a.read(&k, &mut out, &mut scratch);
    }
    assert!(a.pending_len() > 0, "test needs a buffered tail");
    let blob = snapshot::save(&a);
    let mut b = snapshot::restore(&blob).unwrap();
    assert_eq!(b.tokens(), a.tokens());
    assert_eq!(b.state_bytes(), a.state_bytes());
    // continue both past the merge boundary
    for _ in 0..chunk {
        let k = randv(&mut rng, d);
        let v = randv(&mut rng, d);
        let (mut oa, mut ob) = (vec![0.0f32; d], vec![0.0f32; d]);
        a.write(&k, &v);
        a.read(&k, &mut oa, &mut scratch);
        b.write(&k, &v);
        b.read(&k, &mut ob, &mut scratch);
        assert_eq!(oa, ob, "post-restore decode must be bit-identical");
    }
}

#[test]
fn ovq_growth_matches_analytical_schedule_through_trait() {
    // streaming through the trait must hit the same N_t = t*N/(t+N)
    // growth the direct update_chunk path satisfies
    let (d, n_max, chunk) = (8usize, 128usize, 32usize);
    let mut rng = Rng::new(9);
    let mut st = OvqState::new(OvqConfig::new(d, n_max, chunk));
    let mut scratch = Scratch::new();
    let mut out = vec![0.0f32; d];
    for t in 1..=(chunk * 12) {
        let k = randv(&mut rng, d);
        let v = randv(&mut rng, d);
        let q = randv(&mut rng, d);
        st.write(&k, &v);
        st.read(&q, &mut out, &mut scratch);
        assert_eq!(st.tokens(), t);
    }
    st.flush();
    assert_eq!(st.n_active, ovq::ovqcore::growth_n_t(chunk * 12, n_max));
}

#[test]
fn flush_is_idempotent_and_preserves_reads() {
    let (d, total) = (8usize, 40usize);
    let mut rng = Rng::new(5);
    let mut st = OvqState::new(OvqConfig::new(d, 32, 16));
    let mut scratch = Scratch::new();
    for _ in 0..total {
        let k = randv(&mut rng, d);
        let v = randv(&mut rng, d);
        st.write(&k, &v);
    }
    let q = randv(&mut rng, d);
    st.flush();
    let mut a = vec![0.0f32; d];
    st.read(&q, &mut a, &mut scratch);
    st.flush(); // second flush must be a no-op
    let mut b = vec![0.0f32; d];
    st.read(&q, &mut b, &mut scratch);
    assert_eq!(st.t, total);
    assert_eq!(a, b);
}
