//! Integration tests for the HTTP network edge: the socket-replay golden
//! (a zipf trace's completions over a real localhost socket, blocking and
//! SSE-streamed, bit-identical to the in-process run at a different
//! thread count), deterministic overload shedding (engine backpressure,
//! the inflight cap, and the per-tenant bucket all surface as 429 +
//! Retry-After), and a malformed-request sweep over real sockets — every
//! abuse gets a clean typed 4xx, never a panic or a hung connection.
//! All offline (tier-1) — no artifacts or PJRT.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::thread;
use std::time::Duration;

use ovq::coordinator::engine::{DecodeEngine, EngineConfig, EngineHandle};
use ovq::coordinator::http::{self, HttpConfig, HttpServer};
use ovq::coordinator::sampler::{SamplingParams, StopCriteria};
use ovq::coordinator::traffic::{self, TrafficConfig};
use ovq::ovqcore::lm::{LmConfig, TokenId};
use ovq::ovqcore::memstate::parse_schedule;
use ovq::ovqcore::stack::StackConfig;
use ovq::util::json::Json;
use ovq::util::obs::{self, ObsLevel};

const VOCAB: usize = 32;
const DATA_SEED: u64 = 0xDA7A;

/// The tiny LM most edge tests serve: 1 OVQ layer, dims small enough
/// that full traces stay tier-1-fast.
fn lm_engine(threads: usize) -> DecodeEngine {
    let kinds = parse_schedule("ovq:16", 1).unwrap();
    let lm = LmConfig::new(VOCAB, StackConfig::hybrid(8, 16, 2, 4, 8, kinds));
    let mut cfg = EngineConfig::for_lm(lm);
    cfg.threads = threads;
    cfg.seed = 0x6E6E;
    cfg.prefill_quantum = 16;
    cfg.gen_quantum = 8;
    DecodeEngine::start(cfg)
}

fn greedy_body(session: u64, prompt_len: usize, max_new: usize) -> String {
    let prompt = traffic::synth_tokens(DATA_SEED, session, prompt_len, VOCAB);
    let stop = StopCriteria::max_new(max_new);
    http::completion_body(Some(session), &prompt, &SamplingParams::greedy(), &stop, false)
        .to_string()
}

fn error_code(j: &Json) -> String {
    let code = j.at(&["error", "code"]).and_then(|c| c.as_str());
    code.unwrap_or("<missing>").to_string()
}

// ---------------------------------------------------------------- golden

#[test]
fn socket_replay_is_bit_identical_to_in_process_replay() {
    // the acceptance golden: the same zipf trace's generate requests,
    // served (a) in-process through submit_generate on 1 thread, (b) over
    // a real localhost socket as blocking JSON on 4 threads, and (c) over
    // the socket as SSE streams on 4 threads — token streams must match
    // bit for bit. The in-process run replays the FULL trace (decode and
    // prefill neighbours included), so the comparison also pins that
    // co-resident load never leaks into sampling.
    let gen_lens = vec![6, 10, 16];
    let trace = TrafficConfig::new(16, 120).with_generates(vec![12, 40], gen_lens, 0.9, 0.5);
    let events = traffic::generate(&trace);
    let n_gen = events.iter().filter(|e| e.generate).count();
    assert!(n_gen >= 5, "trace shape drifted: only {n_gen} generate events");

    // (a) in-process reference
    let engine = lm_engine(1);
    traffic::replay(&engine, &events, DATA_SEED, None);
    engine.flush_all();
    let report = engine.finish();
    let mut want: Vec<(u64, Vec<TokenId>)> =
        report.generations.iter().map(|g| (g.session, g.tokens.clone())).collect();
    want.sort_by_key(|(s, _)| *s);
    assert_eq!(want.len(), n_gen, "every generate event must complete");
    assert!(want.iter().all(|(_, t)| !t.is_empty()));

    // (b) and (c): fresh 4-thread engines (a session generates from its
    // first-arrival state, so each wire mode gets an unused engine)
    for stream in [false, true] {
        let engine = lm_engine(4);
        let server = HttpServer::start(HttpConfig::default(), engine.handle()).unwrap();
        let got =
            traffic::replay_over_http(server.addr(), &events, DATA_SEED, VOCAB, stream).unwrap();
        server.stop();
        engine.finish();
        let mode = if stream { "SSE" } else { "blocking" };
        assert_eq!(want, got, "{mode} socket replay diverged from the in-process run");
    }
}

#[test]
fn sse_stream_frames_every_token_then_a_done_record() {
    // SSE framing over a real socket: one data event per token with a
    // running index, a terminal done record repeating the full
    // completion, then the [DONE] sentinel — and the incremental tokens
    // must concatenate to exactly the done record's token list.
    let engine = lm_engine(1);
    let server = HttpServer::start(HttpConfig::default(), engine.handle()).unwrap();
    let prompt = traffic::synth_tokens(DATA_SEED, 3, 12, VOCAB);
    let stop = StopCriteria::max_new(7);
    let body = http::completion_body(Some(3), &prompt, &SamplingParams::greedy(), &stop, true);
    let resp = http::http_post(
        server.addr(),
        "/v1/completions",
        &[],
        body.to_string().as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("content-type"), Some("text/event-stream"));

    let data = resp.sse_data();
    assert_eq!(data.last().map(String::as_str), Some("[DONE]"));
    let done = ovq::util::json::parse(&data[data.len() - 2]).unwrap();
    assert_eq!(done.get("done").and_then(|d| d.as_bool()), Some(true));
    assert_eq!(done.get("finish_reason").and_then(|f| f.as_str()), Some("length"));
    let full = http::token_ids(done.get("tokens").unwrap()).unwrap();
    assert_eq!(full.len(), 7);

    let mut streamed = Vec::new();
    for (i, ev) in data[..data.len() - 2].iter().enumerate() {
        let j = ovq::util::json::parse(ev).unwrap();
        assert_eq!(j.get("index").and_then(|x| x.as_u64()), Some(i as u64));
        streamed.push(j.get("token").and_then(|t| t.as_u64()).unwrap() as TokenId);
    }
    assert_eq!(streamed, full, "incremental tokens must match the done record");
    server.stop();
    engine.finish();
}

// ---------------------------------------------------------- tiered memory

#[test]
fn prefix_forked_completions_match_and_tier_stats_surface() {
    // the memory-tier edge contract: three wire requests sharing one
    // 24-token system prefix — plain (prefix unnamed), builder (first to
    // name it), forked (served from the copy-on-write template) — must
    // return identical greedy completions, on an engine spilling every
    // evicted blob to disk. /v1/stats then surfaces the tier counters.
    use ovq::ovqcore::store::TempDir;
    let dir = TempDir::new("http-tiers");
    let kinds = parse_schedule("ovq:16", 1).unwrap();
    let lm = LmConfig::new(VOCAB, StackConfig::hybrid(8, 16, 2, 4, 8, kinds));
    let mut cfg = EngineConfig::for_lm(lm);
    cfg.threads = 1;
    cfg.seed = 0x6E6E;
    cfg.prefill_quantum = 32;
    cfg.gen_quantum = 8;
    cfg.max_resident = 1;
    cfg.spill_dir = Some(dir.path().to_path_buf());
    cfg.ram_blob_budget = 0;
    let engine = DecodeEngine::start(cfg);
    let server = HttpServer::start(HttpConfig::default(), engine.handle()).unwrap();

    let prefix = traffic::synth_tokens(DATA_SEED, u64::MAX, 24, VOCAB);
    // one shared suffix too: greedy sampling depends only on the prompt
    // and the (session-shared) LM weights, so all three must match
    let suffix = traffic::synth_tokens(DATA_SEED, 12345, 6, VOCAB);
    let post = |session: u64, prefix_len: usize| -> Vec<TokenId> {
        let mut prompt = prefix.clone();
        prompt.extend_from_slice(&suffix);
        let stop = StopCriteria::max_new(8);
        let body = http::completion_body_prefixed(
            Some(session),
            &prompt,
            &SamplingParams::greedy(),
            &stop,
            false,
            prefix_len,
            None,
        );
        let resp = http::http_post(
            server.addr(),
            "/v1/completions",
            &[],
            body.to_string().as_bytes(),
        )
        .unwrap();
        assert_eq!(resp.status, 200, "session {session}");
        http::token_ids(resp.json().unwrap().get("tokens").unwrap()).unwrap()
    };
    let plain = post(1, 0);
    let built = post(2, prefix.len());
    let forked = post(3, prefix.len());
    assert_eq!(plain.len(), 8);
    assert_eq!(plain, built, "naming the prefix changed a completion");
    assert_eq!(plain, forked, "forking the template changed a completion");

    // a fully-covering prefix leaves no token to compute logits from —
    // the edge refuses it as a typed 400 before the engine sees it
    let body = http::completion_body_prefixed(
        Some(4),
        &prefix,
        &SamplingParams::greedy(),
        &StopCriteria::max_new(4),
        false,
        prefix.len(),
        None,
    );
    let resp = http::http_post(
        server.addr(),
        "/v1/completions",
        &[],
        body.to_string().as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 400);
    assert_eq!(error_code(&resp.json().unwrap()), "invalid_param");

    // let the async writebacks land, then read the tier counters
    thread::sleep(Duration::from_millis(200));
    let stats = http::http_get(server.addr(), "/v1/stats").unwrap().json().unwrap();
    let tier = |k: &str| stats.at(&["tiers", k]).and_then(|v| v.as_u64());
    assert_eq!(tier("prefix_hits"), Some(1), "one fork served from the template");
    assert_eq!(tier("prefix_misses"), Some(1), "one build populated it");
    assert_eq!(tier("prefix_entries"), Some(1));
    assert!(tier("prefix_bytes").unwrap() > 0);
    assert!(tier("spills").unwrap() >= 1, "budget 0 under cap-1 churn must spill");
    assert!(tier("disk_restores").is_some() && tier("disk_bytes").is_some());
    server.stop();
    engine.finish();
}

// -------------------------------------------------------------- shedding

/// A meatier LM for the jam test: enough per-token work that a
/// 30k-token generation comfortably outlives the jam/post sequence.
fn heavy_lm_engine() -> DecodeEngine {
    let kinds = parse_schedule("ovq:32", 2).unwrap();
    let lm = LmConfig::new(64, StackConfig::hybrid(32, 64, 2, 16, 16, kinds));
    let mut cfg = EngineConfig::for_lm(lm);
    cfg.threads = 1;
    cfg.queue_depth = 1;
    cfg.seed = 0x6E6E;
    cfg.gen_quantum = 8;
    DecodeEngine::start(cfg)
}

/// Submit 30k-token greedy generations (sessions `offset`, `offset`+1,
/// ...) until the depth-1 queue refuses; returns how many were admitted.
fn jam(handle: &EngineHandle, prompt: &[TokenId], offset: u64) -> usize {
    let mut n = 0usize;
    while handle
        .try_submit_generate(
            offset + n as u64,
            prompt.to_vec(),
            SamplingParams::greedy(),
            StopCriteria::max_new(30_000),
            None,
        )
        .is_ok()
    {
        n += 1;
        assert!(n < 16, "a depth-1 queue never refused");
    }
    n
}

#[test]
fn queue_saturation_sheds_429_with_retry_after() {
    // engine backpressure: jam a 1-worker, depth-1 engine with long
    // greedy generations until the bounded queue refuses in-process.
    // The worker pops exactly one message before its drain gate closes
    // (jobs >= queue_depth suppresses further channel reads until the
    // 30k-token job completes), so once a whole jam round admits
    // nothing on top of >= 2 admissions, the channel is provably full
    // and stays full — the next HTTP completion deterministically hits
    // QueueFull and must come back as 429 overloaded with Retry-After,
    // not block or hang.
    let engine = heavy_lm_engine();
    let server = HttpServer::start(HttpConfig::default(), engine.handle()).unwrap();
    let handle = engine.handle();
    let long_prompt = traffic::synth_tokens(DATA_SEED, 7000, 32, VOCAB);
    let mut jammed = jam(&handle, &long_prompt, 7000);
    assert!(jammed >= 1, "an idle engine must admit the first long job");
    for round in 1..200u64 {
        thread::sleep(Duration::from_millis(5));
        let extra = jam(&handle, &long_prompt, 7000 + round * 100);
        if extra == 0 && jammed >= 2 {
            break;
        }
        jammed += extra;
    }
    assert!(jammed >= 2, "the worker never took the first job in service");

    let resp = http::http_post(
        server.addr(),
        "/v1/completions",
        &[],
        greedy_body(1, 8, 4).as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 429, "a saturated queue must shed, not block");
    let retry = resp.header("retry-after").expect("429 carries Retry-After");
    assert!(retry.parse::<u64>().unwrap() >= 1);
    let j = resp.json().unwrap();
    assert_eq!(error_code(&j), "overloaded");
    assert_eq!(j.at(&["error", "retryable"]).and_then(|r| r.as_bool()), Some(true));

    // later posts may land after the jam clears: each must cleanly be a
    // served 200 or another shed 429 — nothing else, and never a hang
    let mut oks = 0usize;
    for i in 2..5u64 {
        let r = http::http_post(
            server.addr(),
            "/v1/completions",
            &[],
            greedy_body(i, 8, 4).as_bytes(),
        )
        .unwrap();
        match r.status {
            200 => oks += 1,
            429 => assert_eq!(error_code(&r.json().unwrap()), "overloaded"),
            s => panic!("unexpected status {s} under saturation"),
        }
    }

    let stats = http::http_get(server.addr(), "/v1/stats").unwrap().json().unwrap();
    let shed = stats.at(&["shed", "backpressure"]).and_then(|v| v.as_u64());
    assert!(shed.is_some_and(|s| s >= 1), "stats must count the backpressure shed");

    drop(handle);
    server.stop();
    let report = engine.finish();
    assert_eq!(
        report.completions(),
        jammed + oks,
        "every admitted request completes after the jam clears"
    );
}

#[test]
fn inflight_cap_sheds_overloaded_while_health_stays_up() {
    // the global admission cap, pinned deterministically at 0: every
    // completion is refused as 429 overloaded before the engine sees it,
    // while health and stats keep answering 200
    let engine = lm_engine(1);
    let cfg = HttpConfig { max_inflight: 0, ..HttpConfig::default() };
    let server = HttpServer::start(cfg, engine.handle()).unwrap();
    for i in 0..3u64 {
        let resp = http::http_post(
            server.addr(),
            "/v1/completions",
            &[],
            greedy_body(i, 4, 2).as_bytes(),
        )
        .unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(error_code(&resp.json().unwrap()), "overloaded");
        assert!(resp.header("retry-after").is_some());
    }
    let health = http::http_get(server.addr(), "/v1/health").unwrap();
    assert_eq!(health.status, 200);
    let stats = http::http_get(server.addr(), "/v1/stats").unwrap().json().unwrap();
    assert_eq!(stats.at(&["shed", "overloaded"]).and_then(|v| v.as_u64()), Some(3));
    assert_eq!(stats.get("completions").and_then(|v| v.as_u64()), Some(0));
    server.stop();
    engine.finish();
}

#[test]
fn tenant_rate_limit_sheds_429_rate_limited_per_tenant() {
    // per-tenant token buckets: burst 1 at 0.5/s means a tenant's second
    // immediate request is refused with a retry hint, while a different
    // tenant (and the anonymous bucket) are still admitted
    let engine = lm_engine(1);
    let cfg = HttpConfig { tenant_rate: 0.5, tenant_burst: 1.0, ..HttpConfig::default() };
    let server = HttpServer::start(cfg, engine.handle()).unwrap();
    let post = |tenant: Option<&str>, session: u64| {
        let headers: Vec<(&str, &str)> = tenant.map(|t| ("x-tenant", t)).into_iter().collect();
        http::http_post(
            server.addr(),
            "/v1/completions",
            &headers,
            greedy_body(session, 4, 2).as_bytes(),
        )
        .unwrap()
    };
    assert_eq!(post(Some("alice"), 1).status, 200, "burst admits the first request");
    let refused = post(Some("alice"), 2);
    assert_eq!(refused.status, 429, "an empty bucket must refuse");
    assert_eq!(error_code(&refused.json().unwrap()), "rate_limited");
    let retry: u64 = refused.header("retry-after").unwrap().parse().unwrap();
    assert!(retry >= 1);
    assert_eq!(post(Some("bob"), 3).status, 200, "tenants are isolated");
    assert_eq!(post(None, 4).status, 200, "the anonymous bucket is its own tenant");

    let stats = http::http_get(server.addr(), "/v1/stats").unwrap().json().unwrap();
    assert_eq!(stats.at(&["shed", "rate_limited"]).and_then(|v| v.as_u64()), Some(1));
    server.stop();
    engine.finish();
}

// ---------------------------------------------------------- observability

/// Assert one line of Prometheus text exposition is well formed: a
/// `# TYPE <name> <kind>` comment or a `<series> <value>` sample whose
/// value parses as a float and whose metric name is a legal identifier.
fn assert_prometheus_line(line: &str) {
    if let Some(rest) = line.strip_prefix("# TYPE ") {
        let mut it = rest.split_whitespace();
        let name = it.next().expect("TYPE line names a metric");
        let kind = it.next().expect("TYPE line declares a kind");
        assert!(
            name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "bad metric name in {line:?}",
        );
        assert!(
            ["counter", "gauge", "histogram"].contains(&kind),
            "unknown metric kind in {line:?}",
        );
        assert!(it.next().is_none(), "trailing tokens in {line:?}");
        return;
    }
    assert!(!line.starts_with('#'), "unexpected comment form {line:?}");
    let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| {
        panic!("sample line {line:?} has no value");
    });
    assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
    let name = series.split('{').next().unwrap();
    assert!(
        !name.is_empty()
            && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
        "bad series name in {line:?}",
    );
    if series.contains('{') {
        assert!(series.ends_with('}'), "unterminated label set in {line:?}");
    }
}

#[test]
fn metrics_endpoint_serves_well_formed_prometheus_text() {
    // the scrape contract: after real traffic, EVERY line of GET /metrics
    // parses as Prometheus text exposition, and the engine histograms +
    // edge counters are all present with the values the traffic implies
    let engine = lm_engine(2);
    let server = HttpServer::start(HttpConfig::default(), engine.handle()).unwrap();
    for s in 0..3u64 {
        let r = http::http_post(
            server.addr(),
            "/v1/completions",
            &[],
            greedy_body(s, 6, 4).as_bytes(),
        )
        .unwrap();
        assert_eq!(r.status, 200);
    }

    let resp = http::http_get(server.addr(), "/metrics").unwrap();
    assert_eq!(resp.status, 200);
    assert!(
        resp.header("content-type").unwrap().starts_with("text/plain"),
        "metrics must be text exposition, got {:?}",
        resp.header("content-type"),
    );
    let text = String::from_utf8(resp.body.clone()).unwrap();
    for line in text.lines().filter(|l| !l.is_empty()) {
        assert_prometheus_line(line);
    }
    for want in [
        "# TYPE ovq_completion_ns histogram",
        "# TYPE ovq_ttft_ns histogram",
        "ovq_completions_total 3",
        "ovq_http_completions_total 3",
        "ovq_http_requests_total",
        "ovq_queue_depth{shard=\"0\"}",
        "ovq_prefix_hits_total",
        "ovq_tier_spills_total",
    ] {
        assert!(text.contains(want), "metrics output lacks {want:?}:\n{text}");
    }
    // histogram series must carry the cumulative +Inf bucket
    assert!(text.contains("ovq_completion_ns_bucket{le=\"+Inf\"} 3"), "{text}");
    server.stop();
    engine.finish();
}

#[test]
fn trace_endpoint_orders_spans_and_request_ids_propagate() {
    // the tracing contract over a real socket: at --obs trace a
    // completion's spans land in /v1/trace start-ordered, covering the
    // pipeline stages, all carrying the id hashed from the client's
    // x-request-id header — which the response (blocking and SSE) echoes
    // verbatim alongside a consistent timing object.
    obs::set_level(ObsLevel::Trace);
    let engine = lm_engine(2);
    let server = HttpServer::start(HttpConfig::default(), engine.handle()).unwrap();

    let prompt = traffic::synth_tokens(DATA_SEED, 5, 10, VOCAB);
    let stop = StopCriteria::max_new(5);
    let body = http::completion_body(Some(5), &prompt, &SamplingParams::greedy(), &stop, false);
    let resp = http::http_post(
        server.addr(),
        "/v1/completions",
        &[("x-request-id", "e2e-trace-1")],
        body.to_string().as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("x-request-id"), Some("e2e-trace-1"), "echo is verbatim");
    let j = resp.json().unwrap();
    let t = |k: &str| j.at(&["timing", k]).unwrap().as_u64().unwrap();
    assert!(
        t("queue_us") + t("prefill_us") + t("decode_us") <= t("total_us"),
        "timing parts exceed the total",
    );

    // an SSE stream echoes the id on the head and times the done record
    let sse_body =
        http::completion_body(Some(6), &prompt, &SamplingParams::greedy(), &stop, true);
    let sse = http::http_post(
        server.addr(),
        "/v1/completions",
        &[("x-request-id", "e2e-trace-2")],
        sse_body.to_string().as_bytes(),
    )
    .unwrap();
    assert_eq!(sse.header("x-request-id"), Some("e2e-trace-2"));
    let data = sse.sse_data();
    let done = ovq::util::json::parse(&data[data.len() - 2]).unwrap();
    assert!(
        done.at(&["timing", "total_us"]).and_then(|v| v.as_u64()).is_some(),
        "SSE done record lacks timing: {done}",
    );

    let trace = http::http_get(server.addr(), "/v1/trace?n=256").unwrap();
    assert_eq!(trace.status, 200);
    let tj = trace.json().unwrap();
    assert_eq!(tj.get("object").unwrap().as_str(), Some("ovq.trace"));
    let spans = tj.get("spans").unwrap().as_arr().unwrap().to_vec();
    assert!(!spans.is_empty(), "trace level must capture spans");
    let starts: Vec<u64> =
        spans.iter().map(|s| s.get("start_us").unwrap().as_u64().unwrap()).collect();
    assert!(starts.windows(2).all(|w| w[0] <= w[1]), "spans must be start-ordered");

    let want_req = format!("{:x}", obs::hash_request_id("e2e-trace-1"));
    let mine: Vec<&Json> = spans
        .iter()
        .filter(|s| s.get("req").unwrap().as_str() == Some(want_req.as_str()))
        .collect();
    assert!(!mine.is_empty(), "no spans carry the hashed client request id");
    let stages: Vec<&str> =
        mine.iter().filter_map(|s| s.get("stage").unwrap().as_str()).collect();
    for want in ["admission", "queue", "prefill", "sample"] {
        assert!(stages.contains(&want), "stage {want} missing from {stages:?}");
    }
    assert!(
        mine.iter().all(|s| s.get("session").unwrap().as_u64() == Some(5)),
        "request spans must all carry the request's session",
    );

    obs::set_level(ObsLevel::Metrics);
    server.stop();
    engine.finish();
}

// -------------------------------------------------------------- malformed

/// Fire a raw byte blob at the server and return the (lossy) response
/// text — for abuse the well-formed client in `http` cannot produce.
fn raw_exchange(addr: std::net::SocketAddr, payload: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(payload).unwrap();
    s.shutdown(Shutdown::Write).unwrap();
    let mut out = Vec::new();
    let _ = s.read_to_end(&mut out);
    String::from_utf8_lossy(&out).into_owned()
}

#[test]
fn malformed_requests_get_clean_4xx_never_a_panic() {
    // the fuzz sweep, over real sockets: truncated JSON, missing and
    // out-of-range fields, oversized and short-changed bodies, bad verbs,
    // unknown paths, garbage framing — each one a typed 4xx with a stable
    // code, and the server still healthy afterwards
    let engine = lm_engine(1);
    let cfg = HttpConfig { max_body: 256, ..HttpConfig::default() };
    let server = HttpServer::start(cfg, engine.handle()).unwrap();
    let addr = server.addr();

    let post_cases: &[(&str, u16, &str)] = &[
        (r#"{"prompt": [1, 2"#, 400, "bad_json"),
        ("prompt=1,2,3", 400, "bad_json"),
        (r#"{}"#, 400, "missing_field"),
        (r#"{"prompt": "abc"}"#, 400, "invalid_param"),
        (r#"{"prompt": [999]}"#, 400, "invalid_param"),
        (r#"{"prompt": [1], "temperature": -1}"#, 400, "invalid_param"),
        (r#"{"prompt": [1], "max_tokens": 100000}"#, 400, "invalid_param"),
        (r#"{"prompt": [1], "stream": "yes"}"#, 400, "invalid_param"),
    ];
    for (body, status, code) in post_cases {
        let resp = http::http_post(addr, "/v1/completions", &[], body.as_bytes()).unwrap();
        assert_eq!(resp.status, *status, "body {body:?}");
        assert_eq!(error_code(&resp.json().unwrap()), *code, "body {body:?}");
    }

    // oversized body: refused as 413 from the Content-Length alone
    let big = vec![b'x'; 1000];
    let resp = http::http_post(addr, "/v1/completions", &[], &big).unwrap();
    assert_eq!(resp.status, 413);
    assert_eq!(error_code(&resp.json().unwrap()), "body_too_large");

    // wrong verb on known endpoints: 405 with an Allow header
    let resp = http::http_get(addr, "/v1/completions").unwrap();
    assert_eq!(resp.status, 405);
    assert_eq!(resp.header("allow"), Some("POST"));
    assert_eq!(error_code(&resp.json().unwrap()), "method_not_allowed");
    let resp = http::http_post(addr, "/v1/health", &[], b"").unwrap();
    assert_eq!(resp.status, 405);
    assert_eq!(resp.header("allow"), Some("GET"));

    // unknown path
    let resp = http::http_get(addr, "/v1/nope").unwrap();
    assert_eq!(resp.status, 404);
    assert_eq!(error_code(&resp.json().unwrap()), "not_found");

    // body shorter than Content-Length: EOF mid-body is a clean 400
    let short = raw_exchange(
        addr,
        b"POST /v1/completions HTTP/1.1\r\ncontent-length: 50\r\n\r\n{\"pro",
    );
    assert!(short.starts_with("HTTP/1.1 400"), "got: {short}");
    assert!(short.contains("bad_request"), "got: {short}");

    // garbage request line
    let garbage = raw_exchange(addr, b"BLARG\r\n\r\n");
    assert!(garbage.starts_with("HTTP/1.1 400"), "got: {garbage}");

    // a connection dropped before any bytes: no response owed, no panic
    drop(TcpStream::connect(addr).unwrap());

    // after all that abuse the server still serves
    let health = http::http_get(addr, "/v1/health").unwrap();
    assert_eq!(health.status, 200);
    assert_eq!(health.json().unwrap().get("status").and_then(|s| s.as_str()), Some("ok"));
    let stats = http::http_get(addr, "/v1/stats").unwrap().json().unwrap();
    assert!(stats.get("client_errors").and_then(|v| v.as_u64()).unwrap() >= 12);
    server.stop();
    engine.finish();
}
