//! Integration tests over the real PJRT runtime + quickstart artifacts.
//! These require `make artifacts` to have been run (the Makefile test
//! target guarantees it). All tests share one runtime: PJRT CPU clients
//! are heavyweight, so tests run in one process-global client.

use ovq::data::batch::Batch;
use ovq::data::by_name;
use ovq::runtime::Runtime;
use ovq::util::rng::Rng;

// PjRtClient holds raw pointers (not Sync), so each test owns a Runtime;
// run with --test-threads=1 implied by the heavyweight client anyway.
//
// When the PJRT backend is the offline stub (see rust/vendor/xla) or the
// artifacts have not been built (`make artifacts`), these tests skip with
// a notice instead of failing — the pure-Rust ovqcore/golden tests carry
// the offline coverage. Set OVQ_REQUIRE_RUNTIME=1 to turn the skips into
// hard failures (for environments that are supposed to have the real
// backend, so a broken setup can't masquerade as a green suite).
fn mk_rt() -> Option<Runtime> {
    let strict = std::env::var("OVQ_REQUIRE_RUNTIME").is_ok();
    let dir = std::env::var("OVQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("index.json").exists() {
        assert!(
            !strict,
            "OVQ_REQUIRE_RUNTIME set but no artifacts at {dir}/ (run `make artifacts`)"
        );
        eprintln!("skipping runtime test: no artifacts at {dir}/ (run `make artifacts`)");
        return None;
    }
    match Runtime::new(dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            assert!(!strict, "OVQ_REQUIRE_RUNTIME set but runtime unavailable: {e}");
            eprintln!("skipping runtime test: {e}");
            None
        }
    }
}

#[test]
fn init_is_deterministic_in_seed() {
    let Some(rt) = mk_rt() else { return };
    let model = rt.load_model("quickstart").unwrap();
    let a = model.init(7).unwrap();
    let b = model.init(7).unwrap();
    let c = model.init(8).unwrap();
    // compare a randomly-initialized leaf (the embedding) — some leaves
    // (norm gains, log_beta) are constant-initialized by design
    let idx = model
        .manifest
        .params
        .iter()
        .position(|p| p.name.contains("embed"))
        .expect("embed leaf");
    let va = a.params[idx].to_vec::<f32>().unwrap();
    let vb = b.params[idx].to_vec::<f32>().unwrap();
    let vc = c.params[idx].to_vec::<f32>().unwrap();
    assert_eq!(va, vb, "same seed must give identical params");
    assert_ne!(va, vc, "different seeds must differ");
}

#[test]
fn train_step_reduces_loss_on_fixed_batch() {
    let Some(rt) = mk_rt() else { return };
    let model = rt.load_model("quickstart").unwrap();
    let mut state = model.init(1).unwrap();
    let (b, t) = model.train_shape().unwrap();
    let gen = by_name("icr", model.manifest.cfg_usize("vocab", 256)).unwrap();
    let mut rng = Rng::new(3);
    let batch = Batch::generate_train(gen.as_ref(), &mut rng, b, t);
    // repeated steps on the SAME batch must drive the loss down
    let first = model
        .train_step(&mut state, &batch.tokens, &batch.targets, &batch.mask)
        .unwrap()
        .loss;
    let mut last = first;
    for _ in 0..15 {
        last = model
            .train_step(&mut state, &batch.tokens, &batch.targets, &batch.mask)
            .unwrap()
            .loss;
    }
    assert!(
        last < first - 0.05,
        "loss should decrease on a fixed batch: first {first}, last {last}"
    );
}

#[test]
fn eval_consistent_across_calls() {
    let Some(rt) = mk_rt() else { return };
    let model = rt.load_model("quickstart").unwrap();
    let state = model.init(2).unwrap();
    let gen = by_name("icr", model.manifest.cfg_usize("vocab", 256)).unwrap();
    let mut rng = Rng::new(4);
    let batch = Batch::generate(gen.as_ref(), &mut rng, 2, 128);
    let a = model
        .eval("eval_128", &state.params, &batch.tokens, &batch.targets, &batch.mask)
        .unwrap();
    let b = model
        .eval("eval_128", &state.params, &batch.tokens, &batch.targets, &batch.mask)
        .unwrap();
    assert_eq!(a.loss, b.loss, "eval must be deterministic");
    assert_eq!(a.correct, b.correct);
    // correctness never exceeds the mask
    for (c, m) in a.correct.iter().zip(&batch.mask) {
        assert!(*c <= *m + 1e-6);
    }
}

#[test]
fn checkpoint_roundtrip_preserves_training() {
    let Some(rt) = mk_rt() else { return };
    let model = rt.load_model("quickstart").unwrap();
    let mut state = model.init(5).unwrap();
    let (b, t) = model.train_shape().unwrap();
    let gen = by_name("icr", model.manifest.cfg_usize("vocab", 256)).unwrap();
    let mut rng = Rng::new(6);
    let batch = Batch::generate_train(gen.as_ref(), &mut rng, b, t);
    model
        .train_step(&mut state, &batch.tokens, &batch.targets, &batch.mask)
        .unwrap();
    let path = "/tmp/ovq_test_ckpt.bin";
    model.save_checkpoint(&state, path).unwrap();
    let restored = model.load_checkpoint(path).unwrap();
    assert_eq!(restored.step, state.step);
    // one more step from both must produce identical losses
    let m1 = model
        .train_step(&mut state, &batch.tokens, &batch.targets, &batch.mask)
        .unwrap();
    let mut restored = restored;
    let m2 = model
        .train_step(&mut restored, &batch.tokens, &batch.targets, &batch.mask)
        .unwrap();
    assert_eq!(m1.loss, m2.loss, "checkpoint must restore exact state");
    std::fs::remove_file(path).ok();
}

#[test]
fn manifest_matches_artifacts_on_disk() {
    let Some(rt) = mk_rt() else { return };
    let models = rt.list_models().unwrap();
    assert!(models.contains(&"quickstart".to_string()));
    for name in models.iter().take(5) {
        let m = rt.load_model(name).unwrap();
        for (pname, spec) in &m.manifest.programs {
            let p = rt.artifacts_dir.join(&spec.file);
            assert!(p.exists(), "{name}/{pname}: missing {}", p.display());
        }
    }
}

#[test]
fn eval_at_longer_context_than_train_works() {
    // length extrapolation plumbing: eval_256 on a model trained at 128
    let Some(rt) = mk_rt() else { return };
    let model = rt.load_model("quickstart").unwrap();
    let state = model.init(9).unwrap();
    let gen = by_name("icr", model.manifest.cfg_usize("vocab", 256)).unwrap();
    let mut rng = Rng::new(10);
    let batch = Batch::generate(gen.as_ref(), &mut rng, 2, 256);
    let out = model
        .eval("eval_256", &state.params, &batch.tokens, &batch.targets, &batch.mask)
        .unwrap();
    assert!(out.loss.is_finite());
    assert_eq!(out.correct.len(), 2 * 256);
}
