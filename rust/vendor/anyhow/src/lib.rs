//! Offline, API-compatible subset of the `anyhow` crate — just the surface
//! this workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` macros. The registry crate is not
//! fetchable in the offline build environment (DESIGN.md, dependency
//! substitutions); swapping this for the real `anyhow` is a one-line
//! change in rust/Cargo.toml and requires no source edits.

use std::fmt;

/// A context-carrying error. Frames are stored outermost-first, the root
/// cause last — `Display` joins them with ": " like anyhow's `{:#}`.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { frames: vec![m.to_string()] }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.frames.insert(0, c.to_string());
        self
    }

    /// The outermost message (anyhow's `Display`).
    pub fn to_message(&self) -> &str {
        self.frames.first().map(|s| s.as_str()).unwrap_or("unknown error")
    }

    /// Context frames, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.to_message())?;
        if self.frames.len() > 1 {
            writeln!(f, "\nCaused by:")?;
            for (i, frame) in self.frames[1..].iter().enumerate() {
                writeln!(f, "    {i}: {frame}")?;
            }
        }
        Ok(())
    }
}

// NOTE: Error deliberately does NOT implement std::error::Error, exactly
// like the real anyhow — that is what keeps this blanket From coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // include source chain frames when present
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(|| ...)` on Result and Option.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(c)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            // Error::msg, not bail!: stringify! may contain format braces
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/definitely/missing")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn context_chains() {
        let e = io_fail().unwrap_err();
        assert_eq!(e.to_message(), "reading config");
        assert!(e.chain().count() >= 2);
        let disp = format!("{e}");
        assert!(disp.starts_with("reading config: "));
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let e = x.context("missing field").unwrap_err();
        assert_eq!(e.to_message(), "missing field");
    }

    #[test]
    fn bail_formats() {
        fn f(n: usize) -> Result<()> {
            if n > 3 {
                bail!("too big: {n}");
            }
            Ok(())
        }
        assert!(f(2).is_ok());
        assert_eq!(f(9).unwrap_err().to_message(), "too big: 9");
    }
}
