//! Offline stub of the `xla` PJRT bindings — the exact API surface
//! `rust/src/runtime/` consumes, with a fully functional host-side
//! [`Literal`] (so literal construction, checkpointing and their tests
//! work without the native library) and device entry points
//! ([`PjRtClient::cpu`]) that return a descriptive error. Pointing
//! rust/Cargo.toml at the real crates.io `xla` bindings restores the
//! hardware path with no source changes (DESIGN.md, dependency
//! substitutions).

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the native PJRT runtime, which is not linked in \
         this offline build (stub `xla` crate; see DESIGN.md)"
    ))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    U64,
    F32,
    F64,
    Bf16,
    F16,
}

impl ElementType {
    pub fn byte_width(&self) -> usize {
        match self {
            ElementType::Pred => 1,
            ElementType::Bf16 | ElementType::F16 => 2,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
            _ => 4,
        }
    }
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy + Default {
    const TY: ElementType;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
}
impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
}
impl NativeType for u32 {
    const TY: ElementType = ElementType::U32;
}
impl NativeType for f64 {
    const TY: ElementType = ElementType::F64;
}

/// Host-side literal: shape + raw little-endian bytes, or a tuple of
/// literals (the artifact convention returns one tuple per program).
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let expect = dims.iter().product::<usize>() * ty.byte_width();
        if data.len() != expect {
            return Err(Error(format!(
                "literal data is {} bytes, shape {dims:?} x {ty:?} needs {expect}",
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec(), tuple: None })
    }

    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { ty: ElementType::F32, dims: Vec::new(), data: Vec::new(), tuple: Some(elems) }
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len()
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    pub fn shape(&self) -> &[usize] {
        &self.dims
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error("to_vec on a tuple literal".into()));
        }
        if T::TY != self.ty {
            return Err(Error(format!("to_vec type mismatch: literal is {:?}", self.ty)));
        }
        let w = std::mem::size_of::<T>();
        let mut out = vec![T::default(); self.data.len() / w];
        // copy via raw bytes; T is a plain scalar
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.data.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                self.data.len(),
            );
        }
        Ok(out)
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match self.tuple.take() {
            Some(elems) => Ok(elems),
            None => Err(Error("decompose_tuple on a non-tuple literal".into())),
        }
    }
}

/// Parsed HLO module handle. The stub validates that the artifact file
/// exists and is readable but cannot compile it.
pub struct HloModuleProto {
    pub path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        match std::fs::metadata(path) {
            Ok(_) => Ok(HloModuleProto { path: path.to_string() }),
            Err(e) => Err(Error(format!("reading HLO text {path}: {e}"))),
        }
    }
}

pub struct XlaComputation {
    pub path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { path: proto.path.clone() }
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("fetching device buffers"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing a compiled program"))
    }
}

pub struct PjRtClient;

impl PjRtClient {
    /// The stub cannot create a device client; callers are expected to
    /// degrade gracefully (see rust/tests/integration_runtime.rs).
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("creating the PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling HLO"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data = [1.0f32, 2.0, 3.0, 4.0];
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        let l = Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &bytes)
            .unwrap();
        assert_eq!(l.element_count(), 4);
        assert_eq!(l.size_bytes(), 16);
        assert_eq!(l.to_vec::<f32>().unwrap(), data.to_vec());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &[0u8; 8])
                .is_err()
        );
    }

    #[test]
    fn tuple_decompose() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[1, 0, 0, 0])
            .unwrap();
        let mut t = Literal::tuple(vec![a.clone(), a]);
        assert_eq!(t.decompose_tuple().unwrap().len(), 2);
        assert!(t.decompose_tuple().is_err());
    }

    #[test]
    fn client_is_unavailable_with_clear_error() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("offline"));
    }
}
